//! The layer-wise mapper: per unique layer shape, a budgeted search of
//! the enumerated tiling space for the best mapping under an objective
//! (paper §5.1 taken seriously — the adaptive candidate set is the
//! *space the style templates define*, not five hand-picked points).
//!
//! The search reuses the DSE's machinery end to end: candidates come
//! from [`super::tiling::enumerate_all`] (deterministic order,
//! fingerprint-deduplicated, every candidate resolves), budgets are the
//! strategy layer's [`SearchBudget`] (`max_designs` truncates each
//! shape's candidate list deterministically, after a stable
//! defaults-first reorder so the Table 3 bindings are never the ones
//! cut — the cut is the `budget_skipped` counter, exactly like the
//! sweep engine's;
//! `max_seconds` drops later shapes to the Table 3 default bindings so
//! every layer still receives a mapping), and evaluation flows through
//! the shape-memoized [`Analyzer`] — hand the mapper a
//! [`SharedStore`](crate::cache::SharedStore) and same-structure
//! candidates across shapes, PE points, and earlier sweeps replay
//! instead of re-analyzing.
//!
//! Determinism: admission — enumeration, the defaults-first reorder,
//! `max_designs` prefix cuts, and the wall/cancel fallback decision —
//! always runs serially on the coordinating thread, in
//! `Network::unique_shapes` order. Evaluation either folds serially
//! (`threads` = 1, the reference path) or fans each shape's candidate
//! list out in contiguous chunks over a persistent
//! [`crate::util::pool::WavePool`] — the sweep engine's pool — whose
//! results merge in chunk order under the same strict-improvement
//! rule, reproducing the serial fold's earliest-minimum winner
//! exactly. Every pool worker fronts the mapper's own
//! [`SharedStore`], so cross-chunk and cross-shape replays keep
//! working. The outcome — winners, per-shape stats, the assembled
//! network, and every budget counter — is therefore bit-identical
//! across runs, thread counts, and pre-warmed cache states (values are
//! pure functions of keys) as long as no wall-clock budget is set;
//! only the cache hit/miss split and the wall clock may move with the
//! partition, exactly like the sweep's (both are excluded from the
//! contract, see [`MapperStats`]). Pinned in `rust/tests/mapspace.rs`.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::cache::SharedStore;
use crate::dse::strategy::SearchBudget;
use crate::engine::analysis::{
    fold_network_stats, objective_score, Analyzer, LayerStats, NetworkStats, Objective, SkippedLayer,
};
use crate::hw::config::HwConfig;
use crate::ir::dataflow::Dataflow;
use crate::model::layer::{Layer, ShapeKey};
use crate::model::network::{Network, ShapeGroup};
use crate::util::pool::WavePool;

use super::template::StyleTemplate;
use super::tiling::{enumerate_all, enumerate_defaults};

/// Mapper knobs.
#[derive(Debug, Clone)]
pub struct MapperConfig {
    /// Style templates whose tiling spaces are searched (default: all
    /// five Table 3 templates).
    pub templates: Vec<StyleTemplate>,
    /// Per-knob tile resolution (see [`super::tiling::tile_values`]).
    pub tile_resolution: usize,
    /// What "best" means per layer.
    pub objective: Objective,
    /// `max_designs` caps the candidates evaluated *per shape*
    /// (deterministic prefix truncation); `max_seconds` is a whole-run
    /// wall cutoff — shapes reached after it search only the Table 3
    /// default bindings (not bit-deterministic; leave 0.0 when
    /// reproducibility matters).
    pub budget: SearchBudget,
    /// Cooperative cancellation: when set and flipped true, shapes not
    /// yet searched degrade to the Table 3 default bindings — the same
    /// graceful fallback as `budget.max_seconds`, so every layer still
    /// receives a mapping. Scoped per request by the `serve` daemon.
    pub cancel: Option<std::sync::Arc<std::sync::atomic::AtomicBool>>,
    /// Worker threads for candidate evaluation. `1` (the default) is
    /// the serial reference path; `0` means one per available core;
    /// anything else sizes the pool explicitly. Winners, network
    /// stats, and every budget counter are bit-identical for any value
    /// (pinned in `rust/tests/mapspace.rs`) — only the cache hit/miss
    /// split and the wall clock may move.
    pub threads: usize,
}

impl Default for MapperConfig {
    fn default() -> MapperConfig {
        MapperConfig {
            templates: StyleTemplate::all(),
            tile_resolution: 6,
            objective: Objective::Runtime,
            budget: SearchBudget::default(),
            cancel: None,
            threads: 1,
        }
    }
}

impl MapperConfig {
    /// Resolve `threads` = 0 to the machine's parallelism (same rule as
    /// `SweepConfig::effective_threads`).
    fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

/// The chosen mapping for one unique layer shape.
#[derive(Debug, Clone)]
pub struct ShapeMapping {
    /// First layer in network order with this shape.
    pub representative: String,
    /// How many layers share the shape.
    pub members: u64,
    /// The winning mapping.
    pub dataflow: Dataflow,
    /// The winner's stats on the representative layer.
    pub stats: LayerStats,
    /// Candidates admitted to evaluation for this shape.
    pub evaluated: u64,
}

/// Aggregate mapper counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MapperStats {
    /// Unique shapes visited.
    pub shapes: u64,
    /// Knob-value combinations tried across all shapes (pre-validation).
    pub combos: u64,
    /// Distinct mappable candidates after validation + dedup.
    pub candidates: u64,
    /// Candidates actually evaluated (= `candidates` minus budget cuts).
    pub evaluated: u64,
    /// Candidates cut by `budget.max_designs` (per-shape prefix cuts).
    pub budget_skipped: u64,
    /// Shapes that fell back to the Table 3 defaults after the
    /// wall-clock budget expired.
    pub shapes_defaulted: u64,
    /// Analyzer cache hits/misses attributable to this mapper run.
    /// Diagnostic only: under a pooled run the hit/miss split follows
    /// the chunk partition and store warmth (racing chunks can both
    /// miss one key before either publishes it), exactly like
    /// `SweepStats` — the counters are excluded from the determinism
    /// contract.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// The subset of `cache_hits` served by entries a shared store
    /// loaded from a cache file (warm starts; 0 for private stores).
    pub cache_disk_hits: u64,
    /// Entries the backing store's capacity cap dropped during this
    /// run (0 for unbounded stores).
    pub evictions: u64,
    /// The subset of `cache_misses` that skipped the bandwidth-variant
    /// analysis by replaying a memoized
    /// [`crate::engine::profile::ReuseProfile`]. Diagnostic only, like
    /// the hit/miss split.
    pub profile_hits: u64,
    /// Wall-clock seconds.
    pub seconds: f64,
}

impl MapperStats {
    /// One-line human summary. The cache segment is rendered by
    /// [`crate::engine::analysis::fmt_cache_counters`] — the same
    /// formatter `SweepStats::summary` uses, so the mapper reports the
    /// identical mem-hit/disk-hit/miss/eviction split as the sweep.
    pub fn summary(&self) -> String {
        format!(
            "mapspace: shapes={} combos={} candidates={} evaluated={} budget_skipped={} \
             defaulted={} {} wall={:.2}s",
            self.shapes,
            self.combos,
            self.candidates,
            self.evaluated,
            self.budget_skipped,
            self.shapes_defaulted,
            crate::engine::analysis::fmt_cache_counters(
                self.cache_hits,
                self.cache_disk_hits,
                self.cache_misses,
                self.evictions,
                self.profile_hits,
            ),
            self.seconds,
        )
    }
}

/// Result of [`Mapper::map_network`].
#[derive(Debug, Clone)]
pub struct MappingOutcome {
    /// Whole-network stats under the per-shape winners (`dataflow` is
    /// `"mapper"`; layers no candidate maps land in `skipped`).
    pub network: NetworkStats,
    /// The winner per unique shape, in first-occurrence order.
    pub per_shape: Vec<ShapeMapping>,
    pub stats: MapperStats,
}

/// One chunk of a shape's candidate list for the wave pool: the
/// shape's layer, the admitted candidate list (shared), and this
/// chunk's contiguous range within it.
type ChunkJob<'a> = (&'a Layer, Arc<Vec<Dataflow>>, std::ops::Range<usize>);

/// One candidate-chunk search result — the pooled path's job output;
/// the serial path produces exactly one per shape (the whole list as
/// one chunk).
#[derive(Debug, Default)]
struct ChunkSearch {
    /// The chunk-local strict-improvement winner.
    best: Option<(LayerStats, Dataflow)>,
    /// The last failure diagnostic in candidate order.
    last_err: Option<String>,
    /// Candidates evaluated (= chunk length).
    evaluated: u64,
    /// The evaluating analyzer's cache counters (pooled path only; the
    /// serial path reads the mapper's own analyzer deltas instead).
    cache_hits: u64,
    cache_disk_hits: u64,
    cache_misses: u64,
    profile_hits: u64,
}

/// Evaluate a candidate slice in order through `analyzer`, tracking the
/// strict-improvement winner (ties keep the earlier candidate, so the
/// winner is order-stable) and the last failure diagnostic. This is the
/// serial reference loop, shared verbatim by both execution paths: the
/// serial fold runs it over the whole list with the mapper's own
/// analyzer, each pool worker runs it over one contiguous chunk with a
/// per-chunk analyzer fronting the shared store. Chunks merged in chunk
/// order under the same rule ([`merge_chunks`]) reproduce the serial
/// winner bit for bit.
fn search_candidates(
    analyzer: &mut Analyzer,
    layer: &Layer,
    candidates: &[Dataflow],
    hw: &HwConfig,
    objective: Objective,
) -> ChunkSearch {
    let mut out = ChunkSearch::default();
    for df in candidates {
        out.evaluated += 1;
        match analyzer.analyze(layer, df, hw) {
            Ok(s) => {
                let better = match &out.best {
                    None => true,
                    Some((b, _)) => objective_score(&s, objective) < objective_score(b, objective),
                };
                if better {
                    out.best = Some((s, df.clone()));
                }
            }
            // Candidates resolve by construction, but the full analysis
            // can still reject (layer validation, no MACs); record the
            // diagnostic.
            Err(e) => out.last_err = Some(format!("{e:#}")),
        }
    }
    out
}

/// Fold chunk results — **in chunk order** — back into one
/// [`ChunkSearch`], applying the same strict-improvement rule as the
/// inner loop so the earliest candidate achieving the minimum objective
/// wins, exactly as in the serial fold. `last_err` keeps the last
/// diagnostic in candidate order for the same reason.
fn merge_chunks(chunks: Vec<ChunkSearch>, objective: Objective) -> ChunkSearch {
    let mut merged = ChunkSearch::default();
    for chunk in chunks {
        merged.evaluated += chunk.evaluated;
        merged.cache_hits += chunk.cache_hits;
        merged.cache_disk_hits += chunk.cache_disk_hits;
        merged.cache_misses += chunk.cache_misses;
        merged.profile_hits += chunk.profile_hits;
        if let Some((s, df)) = chunk.best {
            let better = match &merged.best {
                None => true,
                Some((b, _)) => objective_score(&s, objective) < objective_score(b, objective),
            };
            if better {
                merged.best = Some((s, df));
            }
        }
        if chunk.last_err.is_some() {
            merged.last_err = chunk.last_err;
        }
    }
    merged
}

/// The layer-wise mapper. Owns an [`Analyzer`] so repeated shapes —
/// within one call and across calls — replay instead of re-analyzing;
/// construct with [`Mapper::with_store`] to pool analyses with sweeps
/// and other mappers (and with `--cache-file` persistence).
#[derive(Debug, Default)]
pub struct Mapper {
    analyzer: Analyzer,
}

impl Mapper {
    pub fn new() -> Mapper {
        Mapper { analyzer: Analyzer::new() }
    }

    pub fn with_store(store: std::sync::Arc<SharedStore>) -> Mapper {
        Mapper { analyzer: Analyzer::with_store(store) }
    }

    /// The underlying analyzer (cache counters, store access).
    pub fn analyzer(&self) -> &Analyzer {
        &self.analyzer
    }

    /// Choose the best mapping per unique layer shape and aggregate the
    /// network under those winners. See the module docs for the search
    /// and determinism contract.
    pub fn map_network(
        &mut self,
        net: &Network,
        hw: &HwConfig,
        cfg: &MapperConfig,
    ) -> Result<MappingOutcome> {
        ensure!(!cfg.templates.is_empty(), "mapper: no style templates to search");
        ensure!(!net.layers.is_empty(), "mapper: empty network");
        let t0 = std::time::Instant::now();
        let (hits0, misses0) = (self.analyzer.cache_hits(), self.analyzer.cache_misses());
        let disk0 = self.analyzer.disk_hits();
        let profile0 = self.analyzer.profile_hits();
        let evictions0 = self.analyzer.store().evictions();
        let mut stats = MapperStats::default();
        let mut per_shape: Vec<ShapeMapping> = Vec::new();
        let mut winners: HashMap<ShapeKey, Dataflow> = HashMap::new();
        let mut failures: HashMap<ShapeKey, String> = HashMap::new();
        // Fingerprints of the Table 3 default bindings, for the
        // defaults-first ordering below.
        let default_fps: std::collections::HashSet<_> = cfg
            .templates
            .iter()
            .map(|t| t.instantiate_defaults().fingerprint())
            .collect();

        // Per-shape candidate admission — everything *before*
        // evaluation, always on the coordinating thread in both paths:
        // the wall/cancel fallback decision, enumeration, the
        // defaults-first reorder, and the `max_designs` prefix cut.
        // Keeping admission serial keeps `shapes_defaulted`, `combos`,
        // `candidates`, and `budget_skipped` bit-identical for any
        // thread count.
        let mut admit = |group: &ShapeGroup<'_>, stats: &mut MapperStats| -> Vec<Dataflow> {
            stats.shapes += 1;
            let cancelled = cfg
                .cancel
                .as_ref()
                .is_some_and(|c| c.load(std::sync::atomic::Ordering::Relaxed));
            let exhausted = cancelled
                || (cfg.budget.max_seconds > 0.0
                    && t0.elapsed().as_secs_f64() >= cfg.budget.max_seconds);
            let en = if exhausted {
                stats.shapes_defaulted += 1;
                enumerate_defaults(&cfg.templates, group.layer, hw.num_pes)
            } else {
                enumerate_all(&cfg.templates, group.layer, hw.num_pes, cfg.tile_resolution)
            };
            stats.combos += en.combos;
            stats.candidates += en.dataflows.len() as u64;
            let mut candidates = en.dataflows;
            // Evaluate the Table 3 default bindings *first* (stable
            // partition: defaults in enumeration order, then the rest),
            // so a `max_designs` prefix cut can never drop the fixed
            // styles — the "mapper cannot lose to a fixed style"
            // guarantee holds for any budget >= the template count
            // (and exactly, unbudgeted).
            candidates.sort_by_key(|df| !default_fps.contains(&df.fingerprint()));
            if cfg.budget.max_designs > 0 && candidates.len() as u64 > cfg.budget.max_designs {
                stats.budget_skipped += candidates.len() as u64 - cfg.budget.max_designs;
                candidates.truncate(cfg.budget.max_designs as usize);
            }
            candidates
        };

        // Record one searched shape's outcome (shared by both paths,
        // in shape order).
        let mut record = |group: &ShapeGroup<'_>, search: ChunkSearch, stats: &mut MapperStats| {
            stats.evaluated += search.evaluated;
            match search.best {
                Some((s, df)) => {
                    winners.insert(group.key, df.clone());
                    per_shape.push(ShapeMapping {
                        representative: group.layer.name.clone(),
                        members: group.count(),
                        dataflow: df,
                        stats: s,
                        evaluated: search.evaluated,
                    });
                }
                None => {
                    failures.insert(
                        group.key,
                        search.last_err.unwrap_or_else(|| "no template mapping resolves".into()),
                    );
                }
            }
        };

        let threads = cfg.effective_threads();
        // Cache counters accumulated from the pooled path's per-chunk
        // analyzers (stay 0 on the serial path, which reads the
        // mapper's own analyzer deltas below).
        let mut pool_counters = (0u64, 0u64, 0u64, 0u64);
        if threads <= 1 {
            // The serial reference: one pass, the mapper's own
            // analyzer, the whole candidate list as a single chunk.
            for group in net.unique_shapes() {
                let candidates = admit(&group, &mut stats);
                let search =
                    search_candidates(&mut self.analyzer, group.layer, &candidates, hw, cfg.objective);
                record(&group, search, &mut stats);
            }
        } else {
            // The pooled path: per-shape candidate chunks as jobs on a
            // persistent [`WavePool`] (the sweep engine's pool,
            // extracted). Each worker evaluates its chunk through a
            // fresh Analyzer fronting the mapper's own store, so
            // cross-chunk and cross-shape replays keep working. Shapes
            // stay sequential — one wave per shape, merged in chunk
            // order — which is what keeps winners and budget accounting
            // bit-identical to the serial fold (module docs).
            let store = Arc::clone(self.analyzer.store());
            let objective = cfg.objective;
            std::thread::scope(|scope| {
                let pool = WavePool::spawn(scope, threads, |(layer, list, range): ChunkJob<'_>| {
                    let mut analyzer = Analyzer::with_store(Arc::clone(&store));
                    let mut out = search_candidates(&mut analyzer, layer, &list[range], hw, objective);
                    out.cache_hits = analyzer.cache_hits();
                    out.cache_disk_hits = analyzer.disk_hits();
                    out.cache_misses = analyzer.cache_misses();
                    out.profile_hits = analyzer.profile_hits();
                    out
                });
                for group in net.unique_shapes() {
                    let candidates = admit(&group, &mut stats);
                    let n = candidates.len();
                    let list = Arc::new(candidates);
                    // Contiguous chunks, a few per worker for load
                    // balance; the partition only affects which worker
                    // evaluates what, never the merged outcome.
                    let chunk = (n / (threads * 4)).max(1);
                    let jobs: Vec<ChunkJob<'_>> = (0..n.div_ceil(chunk))
                        .map(|i| {
                            let start = i * chunk;
                            (group.layer, Arc::clone(&list), start..(start + chunk).min(n))
                        })
                        .collect();
                    let merged = merge_chunks(pool.run_wave(jobs), objective);
                    pool_counters.0 += merged.cache_hits;
                    pool_counters.1 += merged.cache_disk_hits;
                    pool_counters.2 += merged.cache_misses;
                    pool_counters.3 += merged.profile_hits;
                    record(&group, merged, &mut stats);
                }
            });
        }

        // Assemble the network view: every layer replays its shape's
        // winner through the analyzer (cache hits re-labeled with the
        // layer's own name).
        let mut per_layer = Vec::new();
        let mut skipped = Vec::new();
        for layer in &net.layers {
            match winners.get(&layer.shape_key()) {
                Some(df) => per_layer.push(self.analyzer.analyze(layer, df, hw)?),
                None => skipped.push(SkippedLayer {
                    layer: layer.name.clone(),
                    reason: failures
                        .get(&layer.shape_key())
                        .cloned()
                        .unwrap_or_else(|| "no template mapping resolves".into()),
                }),
            }
        }
        ensure!(!per_layer.is_empty(), "mapper: no layer mappable under any template");
        // Pool-worker counters (pooled path; 0 serially) plus the
        // mapper's own analyzer deltas (serial search + assembly).
        let (pool_hits, pool_disk, pool_misses, pool_profile) = pool_counters;
        stats.cache_hits = pool_hits + (self.analyzer.cache_hits() - hits0);
        stats.cache_misses = pool_misses + (self.analyzer.cache_misses() - misses0);
        stats.cache_disk_hits = pool_disk + (self.analyzer.disk_hits() - disk0);
        stats.profile_hits = pool_profile + (self.analyzer.profile_hits() - profile0);
        stats.evictions = self.analyzer.store().evictions().saturating_sub(evictions0);
        stats.seconds = t0.elapsed().as_secs_f64();
        let network = fold_network_stats(&net.name, "mapper", per_layer, skipped);
        Ok(MappingOutcome { network, per_shape, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::vgg16;

    #[test]
    fn mapper_maps_the_vgg_conv_stack() {
        let net = vgg16::conv_only();
        let hw = HwConfig::fig10_default();
        let mut mapper = Mapper::new();
        let out = mapper.map_network(&net, &hw, &MapperConfig::default()).unwrap();
        assert_eq!(out.network.per_layer.len(), net.layers.len());
        assert!(out.network.skipped.is_empty());
        assert_eq!(out.per_shape.len(), net.unique_shapes().len());
        assert_eq!(out.stats.shapes, out.per_shape.len() as u64);
        let members: u64 = out.per_shape.iter().map(|s| s.members).sum();
        assert_eq!(members, net.layers.len() as u64);
        assert!(out.stats.evaluated > 0 && out.stats.candidates >= out.stats.evaluated);
        assert!(out.stats.cache_hits > 0, "repeated shapes + assembly must replay");
        let s = out.stats.summary();
        assert!(s.contains("shapes=") && s.contains("candidates="), "{s}");
        // The cache segment must match the sweep's uniform formatter:
        // mem-hits / disk-hits / misses / evictions.
        assert!(s.contains("h/") && s.contains("d/") && s.contains("m/"), "{s}");
        assert!(s.contains("e wall="), "{s}");
    }

    #[test]
    fn per_shape_budget_truncates_deterministically() {
        let net = vgg16::conv_only();
        let hw = HwConfig::fig10_default();
        let cfg = MapperConfig {
            budget: SearchBudget { max_designs: 3, ..SearchBudget::default() },
            ..MapperConfig::default()
        };
        let mut a = Mapper::new();
        let out_a = a.map_network(&net, &hw, &cfg).unwrap();
        assert!(out_a.stats.budget_skipped > 0, "the smoke shapes enumerate more than 3 candidates");
        assert!(out_a.stats.evaluated <= 3 * out_a.stats.shapes);
        let mut b = Mapper::new();
        let out_b = b.map_network(&net, &hw, &cfg).unwrap();
        assert_eq!(out_a.network.runtime.to_bits(), out_b.network.runtime.to_bits());
        assert_eq!(out_a.stats, MapperStats { seconds: out_a.stats.seconds, ..out_b.stats.clone() });
        for (x, y) in out_a.per_shape.iter().zip(&out_b.per_shape) {
            assert_eq!(x.dataflow, y.dataflow);
        }
    }

    #[test]
    fn budget_never_cuts_the_table3_defaults() {
        // With a budget of exactly the template count, the evaluated
        // prefix is the defaults themselves — so the budgeted mapper
        // still cannot lose to a fixed style (per-layer best over the
        // defaults == adaptive over the fixed Table 3 styles).
        use crate::engine::analysis::adaptive_network;
        use crate::ir::styles;
        let net = vgg16::conv_only();
        let hw = HwConfig::fig10_default();
        let n_templates = StyleTemplate::all().len() as u64;
        let cfg = MapperConfig {
            budget: SearchBudget { max_designs: n_templates, ..SearchBudget::default() },
            ..MapperConfig::default()
        };
        let out = Mapper::new().map_network(&net, &hw, &cfg).unwrap();
        let fixed =
            adaptive_network(&net, &styles::all_styles(), &hw, crate::engine::analysis::Objective::Runtime)
                .unwrap();
        assert_eq!(out.network.per_layer.len(), fixed.per_layer.len());
        assert!(
            out.network.runtime <= fixed.runtime * (1.0 + 1e-9),
            "a defaults-covering budget must not lose to the fixed styles: {} vs {}",
            out.network.runtime,
            fixed.runtime
        );
    }

    #[test]
    fn wall_budget_falls_back_to_defaults_not_failure() {
        let net = vgg16::conv_only();
        let hw = HwConfig::fig10_default();
        let cfg = MapperConfig {
            budget: SearchBudget { max_seconds: 1e-12, ..SearchBudget::default() },
            ..MapperConfig::default()
        };
        let out = Mapper::new().map_network(&net, &hw, &cfg).unwrap();
        assert_eq!(out.network.per_layer.len(), net.layers.len(), "defaults still map every layer");
        assert!(out.stats.shapes_defaulted > 0);
    }
}
