//! The layer-wise mapper: per unique layer shape, a budgeted search of
//! the enumerated tiling space for the best mapping under an objective
//! (paper §5.1 taken seriously — the adaptive candidate set is the
//! *space the style templates define*, not five hand-picked points).
//!
//! The search reuses the DSE's machinery end to end: candidates come
//! from [`super::tiling::enumerate_all`] (deterministic order,
//! fingerprint-deduplicated, every candidate resolves), budgets are the
//! strategy layer's [`SearchBudget`] (`max_designs` truncates each
//! shape's candidate list deterministically, after a stable
//! defaults-first reorder so the Table 3 bindings are never the ones
//! cut — the cut is the `budget_skipped` counter, exactly like the
//! sweep engine's;
//! `max_seconds` drops later shapes to the Table 3 default bindings so
//! every layer still receives a mapping), and evaluation flows through
//! the shape-memoized [`Analyzer`] — hand the mapper a
//! [`SharedStore`](crate::cache::SharedStore) and same-structure
//! candidates across shapes, PE points, and earlier sweeps replay
//! instead of re-analyzing.
//!
//! Determinism: admission — enumeration, the defaults-first reorder,
//! `max_designs` prefix cuts, and the wall/cancel fallback decision —
//! always runs serially on the coordinating thread, in
//! `Network::unique_shapes` order ([`MapDriver::next_wave`]).
//! Evaluation either folds serially (`threads` = 1, the reference
//! path: one chunk per shape) or fans each shape's candidate list out
//! in contiguous chunks over a persistent
//! [`crate::util::pool::WavePool`] — the sweep engine's pool — whose
//! results merge in chunk order under the same strict-improvement
//! rule, reproducing the serial fold's earliest-minimum winner
//! exactly. Every chunk evaluates through an analyzer fronting the
//! mapper's own [`SharedStore`], so cross-chunk and cross-shape
//! replays keep working. The outcome — winners, per-shape stats, the
//! assembled network, and every budget counter — is therefore
//! bit-identical across runs, thread counts, and pre-warmed cache
//! states (values are pure functions of keys) as long as no
//! wall-clock budget is set; only the cache hit/miss split and the
//! wall clock may move with the partition, exactly like the sweep's
//! (both are excluded from the contract, see [`MapperStats`]). Pinned
//! in `rust/tests/mapspace.rs`.
//!
//! The wave loop itself is externalized as [`MapDriver`] (the mirror
//! of [`crate::dse::SweepDriver`]): the `serve` daemon pulls waves
//! from many drivers at once and interleaves their chunks onto one
//! process-wide pool, and [`Mapper::map_network`] is the in-process
//! loop over the same driver.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::cache::SharedStore;
use crate::dse::strategy::SearchBudget;
use crate::engine::analysis::{
    fold_network_stats, objective_score, Analyzer, LayerStats, NetworkStats, Objective, SkippedLayer,
};
use crate::hw::config::HwConfig;
use crate::ir::dataflow::Dataflow;
use crate::model::layer::{Layer, ShapeKey};
use crate::model::network::Network;
use crate::util::pool::WavePool;

use super::template::StyleTemplate;
use super::tiling::{enumerate_all, enumerate_defaults};

/// Mapper knobs.
#[derive(Debug, Clone)]
pub struct MapperConfig {
    /// Style templates whose tiling spaces are searched (default: all
    /// five Table 3 templates).
    pub templates: Vec<StyleTemplate>,
    /// Per-knob tile resolution (see [`super::tiling::tile_values`]).
    pub tile_resolution: usize,
    /// What "best" means per layer.
    pub objective: Objective,
    /// `max_designs` caps the candidates evaluated *per shape*
    /// (deterministic prefix truncation); `max_seconds` is a whole-run
    /// wall cutoff — shapes reached after it search only the Table 3
    /// default bindings (not bit-deterministic; leave 0.0 when
    /// reproducibility matters).
    pub budget: SearchBudget,
    /// Cooperative cancellation: when set and flipped true, shapes not
    /// yet searched degrade to the Table 3 default bindings — the same
    /// graceful fallback as `budget.max_seconds`, so every layer still
    /// receives a mapping. Scoped per request by the `serve` daemon.
    pub cancel: Option<std::sync::Arc<std::sync::atomic::AtomicBool>>,
    /// Worker threads for candidate evaluation. `1` (the default) is
    /// the serial reference path; `0` means one per available core;
    /// anything else sizes the pool explicitly. Winners, network
    /// stats, and every budget counter are bit-identical for any value
    /// (pinned in `rust/tests/mapspace.rs`) — only the cache hit/miss
    /// split and the wall clock may move.
    pub threads: usize,
}

impl Default for MapperConfig {
    fn default() -> MapperConfig {
        MapperConfig {
            templates: StyleTemplate::all(),
            tile_resolution: 6,
            objective: Objective::Runtime,
            budget: SearchBudget::default(),
            cancel: None,
            threads: 1,
        }
    }
}

impl MapperConfig {
    /// Resolve `threads` = 0 to the machine's parallelism (same rule as
    /// `SweepConfig::effective_threads`).
    fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

/// The chosen mapping for one unique layer shape.
#[derive(Debug, Clone)]
pub struct ShapeMapping {
    /// First layer in network order with this shape.
    pub representative: String,
    /// How many layers share the shape.
    pub members: u64,
    /// The winning mapping.
    pub dataflow: Dataflow,
    /// The winner's stats on the representative layer.
    pub stats: LayerStats,
    /// Candidates admitted to evaluation for this shape.
    pub evaluated: u64,
}

/// Aggregate mapper counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MapperStats {
    /// Unique shapes visited.
    pub shapes: u64,
    /// Knob-value combinations tried across all shapes (pre-validation).
    pub combos: u64,
    /// Distinct mappable candidates after validation + dedup.
    pub candidates: u64,
    /// Candidates actually evaluated (= `candidates` minus budget cuts).
    pub evaluated: u64,
    /// Candidates cut by `budget.max_designs` (per-shape prefix cuts).
    pub budget_skipped: u64,
    /// Shapes that fell back to the Table 3 defaults after the
    /// wall-clock budget expired.
    pub shapes_defaulted: u64,
    /// Analyzer cache hits/misses attributable to this mapper run.
    /// Diagnostic only: under a pooled run the hit/miss split follows
    /// the chunk partition and store warmth (racing chunks can both
    /// miss one key before either publishes it), exactly like
    /// `SweepStats` — the counters are excluded from the determinism
    /// contract.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// The subset of `cache_hits` served by entries a shared store
    /// loaded from a cache file (warm starts; 0 for private stores).
    pub cache_disk_hits: u64,
    /// Entries the backing store's capacity cap dropped during this
    /// run (0 for unbounded stores).
    pub evictions: u64,
    /// The subset of `cache_misses` that skipped the bandwidth-variant
    /// analysis by replaying a memoized
    /// [`crate::engine::profile::ReuseProfile`]. Diagnostic only, like
    /// the hit/miss split.
    pub profile_hits: u64,
    /// Wall-clock seconds.
    pub seconds: f64,
}

impl MapperStats {
    /// One-line human summary. The cache segment is rendered by
    /// [`crate::engine::analysis::fmt_cache_counters`] — the same
    /// formatter `SweepStats::summary` uses, so the mapper reports the
    /// identical mem-hit/disk-hit/miss/eviction split as the sweep.
    pub fn summary(&self) -> String {
        format!(
            "mapspace: shapes={} combos={} candidates={} evaluated={} budget_skipped={} \
             defaulted={} {} wall={:.2}s",
            self.shapes,
            self.combos,
            self.candidates,
            self.evaluated,
            self.budget_skipped,
            self.shapes_defaulted,
            crate::engine::analysis::fmt_cache_counters(
                self.cache_hits,
                self.cache_disk_hits,
                self.cache_misses,
                self.evictions,
                self.profile_hits,
            ),
            self.seconds,
        )
    }
}

/// Result of [`Mapper::map_network`].
#[derive(Debug, Clone)]
pub struct MappingOutcome {
    /// Whole-network stats under the per-shape winners (`dataflow` is
    /// `"mapper"`; layers no candidate maps land in `skipped`).
    pub network: NetworkStats,
    /// The winner per unique shape, in first-occurrence order.
    pub per_shape: Vec<ShapeMapping>,
    pub stats: MapperStats,
}

/// One candidate-chunk search result — the pooled path's job output;
/// the serial path produces exactly one per shape (the whole list as
/// one chunk).
#[derive(Debug, Default)]
struct ChunkSearch {
    /// The chunk-local strict-improvement winner.
    best: Option<(LayerStats, Dataflow)>,
    /// The last failure diagnostic in candidate order.
    last_err: Option<String>,
    /// Candidates evaluated (= chunk length).
    evaluated: u64,
    /// The evaluating analyzer's cache counters (pooled path only; the
    /// serial path reads the mapper's own analyzer deltas instead).
    cache_hits: u64,
    cache_disk_hits: u64,
    cache_misses: u64,
    profile_hits: u64,
}

/// Evaluate a candidate slice in order through `analyzer`, tracking the
/// strict-improvement winner (ties keep the earlier candidate, so the
/// winner is order-stable) and the last failure diagnostic. This is the
/// serial reference loop, shared verbatim by both execution paths: the
/// serial fold runs it over the whole list with the mapper's own
/// analyzer, each pool worker runs it over one contiguous chunk with a
/// per-chunk analyzer fronting the shared store. Chunks merged in chunk
/// order under the same rule ([`merge_chunks`]) reproduce the serial
/// winner bit for bit.
fn search_candidates(
    analyzer: &mut Analyzer,
    layer: &Layer,
    candidates: &[Dataflow],
    hw: &HwConfig,
    objective: Objective,
) -> ChunkSearch {
    let mut out = ChunkSearch::default();
    for df in candidates {
        out.evaluated += 1;
        match analyzer.analyze(layer, df, hw) {
            Ok(s) => {
                let better = match &out.best {
                    None => true,
                    Some((b, _)) => objective_score(&s, objective) < objective_score(b, objective),
                };
                if better {
                    out.best = Some((s, df.clone()));
                }
            }
            // Candidates resolve by construction, but the full analysis
            // can still reject (layer validation, no MACs); record the
            // diagnostic.
            Err(e) => out.last_err = Some(format!("{e:#}")),
        }
    }
    out
}

/// Fold chunk results — **in chunk order** — back into one
/// [`ChunkSearch`], applying the same strict-improvement rule as the
/// inner loop so the earliest candidate achieving the minimum objective
/// wins, exactly as in the serial fold. `last_err` keeps the last
/// diagnostic in candidate order for the same reason.
fn merge_chunks(chunks: Vec<ChunkSearch>, objective: Objective) -> ChunkSearch {
    let mut merged = ChunkSearch::default();
    for chunk in chunks {
        merged.evaluated += chunk.evaluated;
        merged.cache_hits += chunk.cache_hits;
        merged.cache_disk_hits += chunk.cache_disk_hits;
        merged.cache_misses += chunk.cache_misses;
        merged.profile_hits += chunk.profile_hits;
        if let Some((s, df)) = chunk.best {
            let better = match &merged.best {
                None => true,
                Some((b, _)) => objective_score(&s, objective) < objective_score(b, objective),
            };
            if better {
                merged.best = Some((s, df));
            }
        }
        if chunk.last_err.is_some() {
            merged.last_err = chunk.last_err;
        }
    }
    merged
}

/// One admitted shape's candidate list, partitioned into contiguous
/// chunks. Cheap to clone (three `Arc`s), so an external scheduler can
/// hand `(wave, chunk_index)` jobs to a shared pool without copying
/// the candidate list.
#[derive(Debug, Clone)]
pub struct MapWave {
    layer: Arc<Layer>,
    list: Arc<Vec<Dataflow>>,
    chunks: Arc<Vec<std::ops::Range<usize>>>,
}

impl MapWave {
    /// Number of chunks this wave splits into (may be 0 when the shape
    /// admitted no candidates — absorb an empty result vector then).
    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }
}

/// The outcome of evaluating one chunk of a [`MapWave`] — opaque to
/// schedulers; hand it back to [`MapDriver::absorb_wave`] in
/// chunk-index order. `Default` is the pool's panic-fill value.
#[derive(Debug, Default)]
pub struct MapChunk(ChunkSearch);

/// The immutable, shareable half of a mapper run: everything a worker
/// needs to evaluate a candidate chunk. Each evaluation runs through a
/// fresh [`Analyzer`] fronting the shared store, so cross-chunk and
/// cross-request replays work no matter which thread runs the chunk.
pub struct MapCtx {
    hw: HwConfig,
    objective: Objective,
    store: Arc<SharedStore>,
}

impl MapCtx {
    /// Evaluate one chunk of a wave. Pure with respect to the driver's
    /// mutable state: any thread may run any chunk in any order, and
    /// results absorb deterministically as long as they are handed
    /// back in chunk-index order.
    pub fn run_chunk(&self, wave: &MapWave, chunk: usize) -> MapChunk {
        let _span = crate::obs::trace::span("map.chunk");
        let mut analyzer = Analyzer::with_store(Arc::clone(&self.store));
        let range = wave.chunks[chunk].clone();
        let mut out =
            search_candidates(&mut analyzer, &wave.layer, &wave.list[range], &self.hw, self.objective);
        out.cache_hits = analyzer.cache_hits();
        out.cache_disk_hits = analyzer.disk_hits();
        out.cache_misses = analyzer.cache_misses();
        out.profile_hits = analyzer.profile_hits();
        MapChunk(out)
    }
}

/// The mapper's per-shape wave loop, externalized (the mapper-side
/// mirror of [`crate::dse::SweepDriver`]): [`MapDriver::next_wave`]
/// runs the serial admission for the next unique shape — the
/// wall/cancel fallback decision, enumeration, the defaults-first
/// reorder, and the `max_designs` prefix cut, exactly as the module
/// docs specify — and partitions the admitted list into chunks; the
/// caller evaluates the chunks however it likes (inline, a private
/// pool, or the `serve` daemon's shared pool) via
/// [`MapCtx::run_chunk`]; [`MapDriver::absorb_wave`] merges them in
/// chunk order and records the shape's winner. [`MapDriver::finish`]
/// assembles the network view through a caller-supplied analyzer
/// (which must front the same store for the replay hits to land).
pub struct MapDriver {
    ctx: Arc<MapCtx>,
    net: Network,
    cfg: MapperConfig,
    /// Thread count the chunk partition is sized for (`<= 1` = one
    /// chunk per shape, the serial reference partition). Affects load
    /// balancing only, never the merged outcome.
    threads: usize,
    default_fps: std::collections::HashSet<crate::cache::DataflowFingerprint>,
    /// Unique shapes in first-occurrence order: (key, representative
    /// layer index, member count) — the owned mirror of
    /// [`Network::unique_shapes`].
    shape_order: Vec<(ShapeKey, usize, u64)>,
    next_shape: usize,
    /// The shape admitted by the last `next_wave`, awaiting absorb.
    current: Option<(ShapeKey, usize, u64)>,
    stats: MapperStats,
    winners: HashMap<ShapeKey, Dataflow>,
    failures: HashMap<ShapeKey, String>,
    per_shape: Vec<ShapeMapping>,
    pool_counters: (u64, u64, u64, u64),
    t0: std::time::Instant,
    evictions0: u64,
}

impl MapDriver {
    /// Set up a mapper run without executing it: validates the config,
    /// snapshots the unique-shape order, and captures the evaluation
    /// context. `cfg.threads` sizes the chunk partition only —
    /// execution belongs to the caller.
    pub fn new(
        net: &Network,
        hw: &HwConfig,
        cfg: &MapperConfig,
        store: Arc<SharedStore>,
    ) -> Result<MapDriver> {
        ensure!(!cfg.templates.is_empty(), "mapper: no style templates to search");
        ensure!(!net.layers.is_empty(), "mapper: empty network");
        let t0 = std::time::Instant::now();
        let evictions0 = store.evictions();
        // Fingerprints of the Table 3 default bindings, for the
        // defaults-first ordering in admission.
        let default_fps: std::collections::HashSet<_> = cfg
            .templates
            .iter()
            .map(|t| t.instantiate_defaults().fingerprint())
            .collect();
        let mut shape_order: Vec<(ShapeKey, usize, u64)> = Vec::new();
        let mut index: HashMap<ShapeKey, usize> = HashMap::new();
        for (i, layer) in net.layers.iter().enumerate() {
            let key = layer.shape_key();
            match index.get(&key).copied() {
                Some(j) => shape_order[j].2 += 1,
                None => {
                    index.insert(key, shape_order.len());
                    shape_order.push((key, i, 1));
                }
            }
        }
        let ctx = Arc::new(MapCtx { hw: hw.clone(), objective: cfg.objective, store });
        Ok(MapDriver {
            ctx,
            net: net.clone(),
            cfg: cfg.clone(),
            threads: cfg.effective_threads(),
            default_fps,
            shape_order,
            next_shape: 0,
            current: None,
            stats: MapperStats::default(),
            winners: HashMap::new(),
            failures: HashMap::new(),
            per_shape: Vec::new(),
            pool_counters: (0, 0, 0, 0),
            t0,
            evictions0,
        })
    }

    /// The shared evaluation context for this run's chunks.
    pub fn ctx(&self) -> Arc<MapCtx> {
        Arc::clone(&self.ctx)
    }

    /// Admit the next unique shape and return its candidate wave, or
    /// `None` when every shape has been visited. Admission —
    /// everything *before* evaluation — always runs here, on the
    /// coordinating thread: the wall/cancel fallback decision,
    /// enumeration, the defaults-first reorder, and the `max_designs`
    /// prefix cut, which keeps `shapes_defaulted`, `combos`,
    /// `candidates`, and `budget_skipped` bit-identical for any
    /// executor. The previous wave must be absorbed first.
    pub fn next_wave(&mut self) -> Option<MapWave> {
        assert!(self.current.is_none(), "absorb the in-flight wave before pulling the next");
        let &(key, rep, members) = self.shape_order.get(self.next_shape)?;
        self.next_shape += 1;
        self.current = Some((key, rep, members));
        let layer = self.net.layers[rep].clone();
        self.stats.shapes += 1;
        let cancelled = self
            .cfg
            .cancel
            .as_ref()
            .is_some_and(|c| c.load(std::sync::atomic::Ordering::Relaxed));
        let exhausted = cancelled
            || (self.cfg.budget.max_seconds > 0.0
                && self.t0.elapsed().as_secs_f64() >= self.cfg.budget.max_seconds);
        let en = if exhausted {
            self.stats.shapes_defaulted += 1;
            enumerate_defaults(&self.cfg.templates, &layer, self.ctx.hw.num_pes)
        } else {
            enumerate_all(&self.cfg.templates, &layer, self.ctx.hw.num_pes, self.cfg.tile_resolution)
        };
        self.stats.combos += en.combos;
        self.stats.candidates += en.dataflows.len() as u64;
        let mut candidates = en.dataflows;
        // Evaluate the Table 3 default bindings *first* (stable
        // partition: defaults in enumeration order, then the rest),
        // so a `max_designs` prefix cut can never drop the fixed
        // styles — the "mapper cannot lose to a fixed style"
        // guarantee holds for any budget >= the template count
        // (and exactly, unbudgeted).
        candidates.sort_by_key(|df| !self.default_fps.contains(&df.fingerprint()));
        if self.cfg.budget.max_designs > 0 && candidates.len() as u64 > self.cfg.budget.max_designs
        {
            self.stats.budget_skipped += candidates.len() as u64 - self.cfg.budget.max_designs;
            candidates.truncate(self.cfg.budget.max_designs as usize);
        }
        let n = candidates.len();
        // Contiguous chunks, a few per worker for load balance (one
        // chunk — the serial reference partition — when `threads` <=
        // 1); the partition only affects which worker evaluates what,
        // never the merged outcome.
        let chunk = if self.threads <= 1 { n.max(1) } else { (n / (self.threads * 4)).max(1) };
        let chunks: Vec<std::ops::Range<usize>> = (0..n.div_ceil(chunk))
            .map(|i| {
                let start = i * chunk;
                start..(start + chunk).min(n)
            })
            .collect();
        Some(MapWave { layer: Arc::new(layer), list: Arc::new(candidates), chunks: Arc::new(chunks) })
    }

    /// Merge one wave's chunk results — **in chunk-index order** — and
    /// record the shape's winner (or failure diagnostic).
    pub fn absorb_wave(&mut self, chunks: Vec<MapChunk>) {
        let (key, rep, members) =
            self.current.take().expect("absorb_wave without a wave in flight");
        let merged =
            merge_chunks(chunks.into_iter().map(|c| c.0).collect(), self.ctx.objective);
        self.pool_counters.0 += merged.cache_hits;
        self.pool_counters.1 += merged.cache_disk_hits;
        self.pool_counters.2 += merged.cache_misses;
        self.pool_counters.3 += merged.profile_hits;
        self.stats.evaluated += merged.evaluated;
        match merged.best {
            Some((s, df)) => {
                self.winners.insert(key, df.clone());
                self.per_shape.push(ShapeMapping {
                    representative: self.net.layers[rep].name.clone(),
                    members,
                    dataflow: df,
                    stats: s,
                    evaluated: merged.evaluated,
                });
            }
            None => {
                self.failures.insert(
                    key,
                    merged.last_err.unwrap_or_else(|| "no template mapping resolves".into()),
                );
            }
        }
    }

    /// Unique shapes in the workload (the total wave count).
    pub fn shapes_total(&self) -> usize {
        self.shape_order.len()
    }

    /// Shapes admitted so far (in-flight wave included).
    pub fn shapes_admitted(&self) -> usize {
        self.next_shape
    }

    /// Candidates evaluated so far.
    pub fn evaluated(&self) -> u64 {
        self.stats.evaluated
    }

    /// Assemble the network view: every layer replays its shape's
    /// winner through `analyzer` (cache hits re-labeled with the
    /// layer's own name), then the counters finalize. `analyzer` must
    /// front the same store as the driver for the replays to hit.
    pub fn finish(mut self, analyzer: &mut Analyzer) -> Result<MappingOutcome> {
        let (hits0, misses0) = (analyzer.cache_hits(), analyzer.cache_misses());
        let disk0 = analyzer.disk_hits();
        let profile0 = analyzer.profile_hits();
        let mut per_layer = Vec::new();
        let mut skipped = Vec::new();
        for layer in &self.net.layers {
            match self.winners.get(&layer.shape_key()) {
                Some(df) => per_layer.push(analyzer.analyze(layer, df, &self.ctx.hw)?),
                None => skipped.push(SkippedLayer {
                    layer: layer.name.clone(),
                    reason: self
                        .failures
                        .get(&layer.shape_key())
                        .cloned()
                        .unwrap_or_else(|| "no template mapping resolves".into()),
                }),
            }
        }
        ensure!(!per_layer.is_empty(), "mapper: no layer mappable under any template");
        // Chunk-worker counters plus the assembly analyzer's deltas.
        let (pool_hits, pool_disk, pool_misses, pool_profile) = self.pool_counters;
        self.stats.cache_hits = pool_hits + (analyzer.cache_hits() - hits0);
        self.stats.cache_misses = pool_misses + (analyzer.cache_misses() - misses0);
        self.stats.cache_disk_hits = pool_disk + (analyzer.disk_hits() - disk0);
        self.stats.profile_hits = pool_profile + (analyzer.profile_hits() - profile0);
        self.stats.evictions = self.ctx.store.evictions().saturating_sub(self.evictions0);
        self.stats.seconds = self.t0.elapsed().as_secs_f64();
        let network = fold_network_stats(&self.net.name, "mapper", per_layer, skipped);
        Ok(MappingOutcome { network, per_shape: self.per_shape, stats: self.stats })
    }
}

/// The layer-wise mapper. Owns an [`Analyzer`] so repeated shapes —
/// within one call and across calls — replay instead of re-analyzing;
/// construct with [`Mapper::with_store`] to pool analyses with sweeps
/// and other mappers (and with `--cache-file` persistence).
#[derive(Debug, Default)]
pub struct Mapper {
    analyzer: Analyzer,
}

impl Mapper {
    pub fn new() -> Mapper {
        Mapper { analyzer: Analyzer::new() }
    }

    pub fn with_store(store: std::sync::Arc<SharedStore>) -> Mapper {
        Mapper { analyzer: Analyzer::with_store(store) }
    }

    /// The underlying analyzer (cache counters, store access).
    pub fn analyzer(&self) -> &Analyzer {
        &self.analyzer
    }

    /// Choose the best mapping per unique layer shape and aggregate the
    /// network under those winners. See the module docs for the search
    /// and determinism contract.
    ///
    /// This is the in-process convenience loop over [`MapDriver`]:
    /// serial admission per shape, chunk evaluation inline (`threads`
    /// <= 1, the reference partition: one chunk per shape) or on a
    /// private persistent [`WavePool`], chunk-order merge, and assembly
    /// through the mapper's own analyzer. The `serve` daemon drives the
    /// same [`MapDriver`] from its shared scheduler instead, so daemon
    /// replies inherit the determinism contract.
    pub fn map_network(
        &mut self,
        net: &Network,
        hw: &HwConfig,
        cfg: &MapperConfig,
    ) -> Result<MappingOutcome> {
        let mut driver = MapDriver::new(net, hw, cfg, Arc::clone(self.analyzer.store()))?;
        let threads = cfg.effective_threads();
        if threads <= 1 {
            // Serial: evaluate each shape's single chunk inline.
            let ctx = driver.ctx();
            while let Some(wave) = driver.next_wave() {
                let chunks =
                    (0..wave.chunk_count()).map(|chunk| ctx.run_chunk(&wave, chunk)).collect();
                driver.absorb_wave(chunks);
            }
        } else {
            // Pooled: per-shape candidate chunks as jobs on a
            // persistent [`WavePool`] (the sweep engine's pool,
            // extracted). Shapes stay sequential — one wave per shape,
            // merged in chunk order — which is what keeps winners and
            // budget accounting bit-identical to the serial fold
            // (module docs).
            let ctx = driver.ctx();
            let ctx: &MapCtx = &ctx;
            std::thread::scope(|scope| {
                let pool = WavePool::spawn(scope, threads, move |(wave, chunk): (MapWave, usize)| {
                    ctx.run_chunk(&wave, chunk)
                });
                while let Some(wave) = driver.next_wave() {
                    let jobs: Vec<(MapWave, usize)> =
                        (0..wave.chunk_count()).map(|chunk| (wave.clone(), chunk)).collect();
                    driver.absorb_wave(pool.run_wave(jobs));
                }
            });
        }
        driver.finish(&mut self.analyzer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::vgg16;

    #[test]
    fn mapper_maps_the_vgg_conv_stack() {
        let net = vgg16::conv_only();
        let hw = HwConfig::fig10_default();
        let mut mapper = Mapper::new();
        let out = mapper.map_network(&net, &hw, &MapperConfig::default()).unwrap();
        assert_eq!(out.network.per_layer.len(), net.layers.len());
        assert!(out.network.skipped.is_empty());
        assert_eq!(out.per_shape.len(), net.unique_shapes().len());
        assert_eq!(out.stats.shapes, out.per_shape.len() as u64);
        let members: u64 = out.per_shape.iter().map(|s| s.members).sum();
        assert_eq!(members, net.layers.len() as u64);
        assert!(out.stats.evaluated > 0 && out.stats.candidates >= out.stats.evaluated);
        assert!(out.stats.cache_hits > 0, "repeated shapes + assembly must replay");
        let s = out.stats.summary();
        assert!(s.contains("shapes=") && s.contains("candidates="), "{s}");
        // The cache segment must match the sweep's uniform formatter:
        // mem-hits / disk-hits / misses / evictions.
        assert!(s.contains("h/") && s.contains("d/") && s.contains("m/"), "{s}");
        assert!(s.contains("e wall="), "{s}");
    }

    #[test]
    fn per_shape_budget_truncates_deterministically() {
        let net = vgg16::conv_only();
        let hw = HwConfig::fig10_default();
        let cfg = MapperConfig {
            budget: SearchBudget { max_designs: 3, ..SearchBudget::default() },
            ..MapperConfig::default()
        };
        let mut a = Mapper::new();
        let out_a = a.map_network(&net, &hw, &cfg).unwrap();
        assert!(out_a.stats.budget_skipped > 0, "the smoke shapes enumerate more than 3 candidates");
        assert!(out_a.stats.evaluated <= 3 * out_a.stats.shapes);
        let mut b = Mapper::new();
        let out_b = b.map_network(&net, &hw, &cfg).unwrap();
        assert_eq!(out_a.network.runtime.to_bits(), out_b.network.runtime.to_bits());
        assert_eq!(out_a.stats, MapperStats { seconds: out_a.stats.seconds, ..out_b.stats.clone() });
        for (x, y) in out_a.per_shape.iter().zip(&out_b.per_shape) {
            assert_eq!(x.dataflow, y.dataflow);
        }
    }

    #[test]
    fn budget_never_cuts_the_table3_defaults() {
        // With a budget of exactly the template count, the evaluated
        // prefix is the defaults themselves — so the budgeted mapper
        // still cannot lose to a fixed style (per-layer best over the
        // defaults == adaptive over the fixed Table 3 styles).
        use crate::engine::analysis::adaptive_network;
        use crate::ir::styles;
        let net = vgg16::conv_only();
        let hw = HwConfig::fig10_default();
        let n_templates = StyleTemplate::all().len() as u64;
        let cfg = MapperConfig {
            budget: SearchBudget { max_designs: n_templates, ..SearchBudget::default() },
            ..MapperConfig::default()
        };
        let out = Mapper::new().map_network(&net, &hw, &cfg).unwrap();
        let fixed =
            adaptive_network(&net, &styles::all_styles(), &hw, crate::engine::analysis::Objective::Runtime)
                .unwrap();
        assert_eq!(out.network.per_layer.len(), fixed.per_layer.len());
        assert!(
            out.network.runtime <= fixed.runtime * (1.0 + 1e-9),
            "a defaults-covering budget must not lose to the fixed styles: {} vs {}",
            out.network.runtime,
            fixed.runtime
        );
    }

    #[test]
    fn wall_budget_falls_back_to_defaults_not_failure() {
        let net = vgg16::conv_only();
        let hw = HwConfig::fig10_default();
        let cfg = MapperConfig {
            budget: SearchBudget { max_seconds: 1e-12, ..SearchBudget::default() },
            ..MapperConfig::default()
        };
        let out = Mapper::new().map_network(&net, &hw, &cfg).unwrap();
        assert_eq!(out.network.per_layer.len(), net.layers.len(), "defaults still map every layer");
        assert!(out.stats.shapes_defaulted > 0);
    }
}
