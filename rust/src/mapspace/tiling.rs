//! Programmatic tiling enumeration: from a [`StyleTemplate`] and a
//! concrete layer, the legal tile-size bindings of every declared knob.
//!
//! # Enumeration bounds (the honest version)
//!
//! This is *not* the full tiling space of the layer. Per knob,
//! [`tile_values`] emits at most `resolution` candidate sizes — a
//! deterministic subsample of the divisors of the dimension extent
//! (edge-free tilings) unioned with the powers of two up to it
//! (edge-tile tilings) — plus the knob's Table 3 default, which is
//! always included so the enumerated space is a superset of the fixed
//! evaluation style whenever that style maps. The full grid is the
//! product over knobs (so at most `(resolution + 1)^knobs` bindings per
//! template), each instantiated and validated with
//! [`Dataflow::resolve`] against the source layer at the stated PE
//! count — every emitted candidate maps — and deduplicated by
//! structural [`fingerprint`](Dataflow::fingerprint) (distinct knob
//! values can collapse to one structure, e.g. clamped tiles).
//! Everything here is a pure function of its arguments: enumeration
//! order is bit-deterministic for any caller, thread, or process
//! (pinned by `rust/tests/mapspace.rs`).

use std::collections::HashSet;

use crate::ir::dataflow::Dataflow;
use crate::model::layer::Layer;

use super::template::{StyleTemplate, TileKnob, TileRule};

/// Candidate tile sizes for one knob over a dimension of `extent`:
/// divisors and/or powers-of-two covers per the knob's [`TileRule`],
/// subsampled to at most `resolution` values (evenly spaced over the
/// sorted candidate list, extremes kept), with the Table 3 `default`
/// always merged in. Ascending, deduplicated, deterministic.
/// Resolutions below 2 are clamped to 2 (the extremes are always kept,
/// so 2 is the smallest meaningful subsample).
pub fn tile_values(extent: u64, rule: TileRule, resolution: usize, default: u64) -> Vec<u64> {
    let resolution = resolution.max(2);
    let extent = extent.max(1);
    let mut vals: Vec<u64> = Vec::new();
    if matches!(rule, TileRule::Divisors | TileRule::DivisorsAndCover) {
        vals.extend((1..=extent).filter(|d| extent % d == 0));
    }
    if matches!(rule, TileRule::Cover | TileRule::DivisorsAndCover) {
        let mut p = 1u64;
        while p <= extent {
            vals.push(p);
            match p.checked_mul(2) {
                Some(next) => p = next,
                None => break,
            }
        }
        vals.push(extent);
    }
    vals.sort_unstable();
    vals.dedup();
    if vals.len() > resolution {
        let last = vals.len() - 1;
        let picked: Vec<u64> = (0..resolution).map(|i| vals[i * last / (resolution - 1)]).collect();
        vals = picked;
        vals.dedup();
    }
    if let Err(at) = vals.binary_search(&default) {
        vals.insert(at, default);
    }
    vals
}

/// Result of enumerating one template (or a template set) on a layer.
#[derive(Debug, Clone, Default)]
pub struct Enumeration {
    /// The fingerprint-deduplicated, resolve-validated mappings, in
    /// deterministic odometer order (last knob fastest; templates in
    /// input order for [`enumerate_all`]).
    pub dataflows: Vec<Dataflow>,
    /// Knob values behind each dataflow (parallel to `dataflows`;
    /// empty inner vec for knobless templates). These are the *tile
    /// coordinates* guided search uses for adjacency.
    pub coords: Vec<Vec<u64>>,
    /// Index of the source template per dataflow (parallel to
    /// `dataflows`; position in the template list handed to
    /// [`enumerate_all`]/[`enumerate_defaults`], always 0 for
    /// [`enumerate`]). Tile coordinates only compare within one
    /// template — [`tile_adjacency`] requires it.
    pub template_of: Vec<usize>,
    /// Knob-value combinations tried (pre-validation).
    pub combos: u64,
    /// Combinations whose instantiation failed to resolve on the layer.
    pub unmappable: u64,
    /// Combinations dropped as structural duplicates of an earlier one.
    pub duplicates: u64,
}

impl Enumeration {
    fn absorb(&mut self, other: Enumeration) {
        self.combos += other.combos;
        self.unmappable += other.unmappable;
        self.duplicates += other.duplicates;
        self.dataflows.extend(other.dataflows);
        self.coords.extend(other.coords);
        self.template_of.extend(other.template_of);
    }
}

/// Enumerate the legal tile bindings of `template` on `layer`,
/// validated at `pes` processing elements. See the module docs for the
/// exact bounds.
pub fn enumerate(template: &StyleTemplate, layer: &Layer, pes: u64, resolution: usize) -> Enumeration {
    let axes: Vec<Vec<u64>> = template
        .knobs
        .iter()
        .map(|k: &TileKnob| tile_values(layer.dim(k.dim), k.rule, resolution, k.default))
        .collect();
    enumerate_axes(template, 0, layer, pes, &axes, &mut HashSet::new())
}

/// Enumerate every template of a set on one layer, deduplicating
/// structures *across* templates (first template wins a shared
/// fingerprint). This is the mapper's per-shape candidate list.
pub fn enumerate_all(
    templates: &[StyleTemplate],
    layer: &Layer,
    pes: u64,
    resolution: usize,
) -> Enumeration {
    let mut seen = HashSet::new();
    let mut out = Enumeration::default();
    for (ti, t) in templates.iter().enumerate() {
        let axes: Vec<Vec<u64>> = t
            .knobs
            .iter()
            .map(|k| tile_values(layer.dim(k.dim), k.rule, resolution, k.default))
            .collect();
        out.absorb(enumerate_axes(t, ti, layer, pes, &axes, &mut seen));
    }
    out
}

/// Just the Table 3 default binding of each template (the fixed
/// evaluation styles), resolve-validated and deduplicated — the
/// mapper's fallback candidate list once a wall-clock budget is spent.
pub fn enumerate_defaults(templates: &[StyleTemplate], layer: &Layer, pes: u64) -> Enumeration {
    let mut seen = HashSet::new();
    let mut out = Enumeration::default();
    for (ti, t) in templates.iter().enumerate() {
        let axes: Vec<Vec<u64>> = t.knobs.iter().map(|k| vec![k.default]).collect();
        out.absorb(enumerate_axes(t, ti, layer, pes, &axes, &mut seen));
    }
    out
}

fn enumerate_axes(
    template: &StyleTemplate,
    template_idx: usize,
    layer: &Layer,
    pes: u64,
    axes: &[Vec<u64>],
    seen: &mut HashSet<crate::cache::DataflowFingerprint>,
) -> Enumeration {
    let mut out = Enumeration::default();
    let mut consider = |combo: &[u64], out: &mut Enumeration| {
        out.combos += 1;
        let df = template.instantiate(combo);
        if df.resolve(layer, pes).is_err() {
            out.unmappable += 1;
            return;
        }
        if !seen.insert(df.fingerprint()) {
            out.duplicates += 1;
            return;
        }
        out.dataflows.push(df);
        out.coords.push(combo.to_vec());
        out.template_of.push(template_idx);
    };
    if axes.is_empty() {
        consider(&[], &mut out);
        return out;
    }
    if axes.iter().any(|a| a.is_empty()) {
        return out;
    }
    // Odometer over the knob axes, last knob fastest (matches
    // `StyleTemplate::instantiate_grid` and the legacy variant lists).
    let mut idx = vec![0usize; axes.len()];
    let mut combo: Vec<u64> = axes.iter().map(|a| a[0]).collect();
    loop {
        consider(&combo, &mut out);
        let mut k = axes.len();
        loop {
            if k == 0 {
                return out;
            }
            k -= 1;
            idx[k] += 1;
            if idx[k] < axes[k].len() {
                combo[k] = axes[k][idx[k]];
                break;
            }
            idx[k] = 0;
            combo[k] = axes[k][0];
        }
    }
}

/// Tile-coordinate adjacency over an enumeration's surviving
/// candidates: `j` neighbors `i` when they come from the *same
/// template* (`template_of`, parallel to `coords` — knob values from
/// different templates are incomparable even at equal arity), their
/// coordinates differ in exactly one knob, and no surviving candidate
/// sits strictly between them on that knob (with every other knob
/// equal) — one step in tile space, robust to the holes validation and
/// dedup punch into the grid. Deterministic: neighbors ascend by
/// index.
pub fn tile_adjacency(coords: &[Vec<u64>], template_of: &[usize]) -> Vec<Vec<usize>> {
    assert_eq!(coords.len(), template_of.len(), "parallel slices from one Enumeration");
    let n = coords.len();
    let mut adj = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i == j
                || template_of[i] != template_of[j]
                || coords[i].len() != coords[j].len()
                || coords[i].is_empty()
            {
                continue;
            }
            let (a, b) = (&coords[i], &coords[j]);
            let mut diff = None;
            let mut multi = false;
            for d in 0..a.len() {
                if a[d] != b[d] {
                    if diff.is_some() {
                        multi = true;
                        break;
                    }
                    diff = Some(d);
                }
            }
            let Some(d) = diff else { continue };
            if multi {
                continue;
            }
            let (lo, hi) = (a[d].min(b[d]), a[d].max(b[d]));
            let between = coords.iter().enumerate().any(|(k, c)| {
                k != i
                    && k != j
                    && template_of[k] == template_of[i]
                    && c.len() == a.len()
                    && c[d] > lo
                    && c[d] < hi
                    && (0..a.len()).all(|e| e == d || c[e] == a[e])
            });
            if !between {
                adj[i].push(j);
            }
        }
    }
    adj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::vgg16;

    #[test]
    fn tile_values_cover_extremes_and_respect_resolution() {
        let v = tile_values(64, TileRule::DivisorsAndCover, 4, 64);
        assert_eq!(v.first(), Some(&1));
        assert_eq!(v.last(), Some(&64));
        assert!(v.windows(2).all(|w| w[0] < w[1]), "{v:?}");
        assert!(v.len() <= 5, "resolution + default bound: {v:?}");
        // The default is always present, even above the extent.
        let v = tile_values(3, TileRule::DivisorsAndCover, 4, 64);
        assert!(v.contains(&64), "{v:?}");
        assert!(v.contains(&3));
    }

    #[test]
    fn tile_values_divisors_only_divide() {
        let v = tile_values(12, TileRule::Divisors, 16, 4);
        assert!(v.iter().all(|&d| 12 % d == 0), "{v:?}");
        assert_eq!(v, vec![1, 2, 3, 4, 6, 12]);
    }

    #[test]
    fn tile_values_clamps_degenerate_resolutions() {
        // User-supplied resolutions of 0/1 must not panic: they clamp
        // to 2 (extremes), plus the always-kept default.
        for resolution in [0usize, 1] {
            let v = tile_values(64, TileRule::DivisorsAndCover, resolution, 8);
            assert_eq!(v, vec![1, 8, 64], "resolution {resolution}");
        }
    }

    #[test]
    fn enumeration_validates_dedupes_and_accounts() {
        let layer = vgg16::conv13();
        let t = StyleTemplate::kc_p();
        let en = enumerate(&t, &layer, 256, 6);
        assert!(!en.dataflows.is_empty());
        assert_eq!(en.dataflows.len(), en.coords.len());
        assert_eq!(
            en.combos,
            en.dataflows.len() as u64 + en.unmappable + en.duplicates,
            "every combination lands in exactly one bucket"
        );
        for df in &en.dataflows {
            df.resolve(&layer, 256).expect("every emitted candidate maps");
        }
        // conv13 has C=512: ct=512 needs a 512-wide cluster, which 256
        // PEs cannot host — enumeration must have filtered it.
        assert!(en.unmappable > 0, "oversized cluster tiles must be filtered");
    }

    #[test]
    fn enumerate_all_includes_every_fixed_style_that_maps() {
        use crate::ir::styles;
        let layer = vgg16::conv2();
        let en = enumerate_all(&StyleTemplate::all(), &layer, 256, 2);
        for fixed in styles::all_styles() {
            if fixed.resolve(&layer, 256).is_ok() {
                assert!(
                    en.dataflows.iter().any(|d| d.fingerprint() == fixed.fingerprint()),
                    "{} missing from the enumeration even at minimum resolution",
                    fixed.name
                );
            }
        }
    }

    #[test]
    fn adjacency_is_one_tile_step() {
        // A 1-knob axis with a hole: 1 - 2 - 8 (4 was filtered out).
        let coords = vec![vec![1], vec![2], vec![8]];
        let adj = tile_adjacency(&coords, &[0, 0, 0]);
        assert_eq!(adj, vec![vec![1], vec![0, 2], vec![1]]);
        // A 2-knob grid: (1,1) (1,2) (2,1) (2,2) — diagonals excluded.
        let grid = vec![vec![1, 1], vec![1, 2], vec![2, 1], vec![2, 2]];
        let adj = tile_adjacency(&grid, &[0; 4]);
        assert_eq!(adj[0], vec![1, 2]);
        assert_eq!(adj[3], vec![1, 2]);
        // Knobless candidates have no tile neighbors.
        assert_eq!(tile_adjacency(&[vec![], vec![]], &[0, 1]), vec![Vec::<usize>::new(); 2]);
    }

    #[test]
    fn adjacency_never_crosses_templates() {
        // Same knob arity, different source templates (kc-p ct vs
        // yx-p xt): values are incomparable, so no adjacency.
        let coords = vec![vec![4], vec![8], vec![4], vec![8]];
        let adj = tile_adjacency(&coords, &[0, 0, 1, 1]);
        assert_eq!(adj, vec![vec![1], vec![0], vec![3], vec![2]]);
    }
}
