//! Style templates: the Table 3 dataflow styles with their tileable
//! dimensions *declared* instead of baked in.
//!
//! A [`StyleTemplate`] is the paper's §2.4 dataflow-vs-mapping split
//! made programmatic: the directive skeleton (the dataflow) is fixed by
//! the template's builder, while each declared [`TileKnob`] names a
//! free tile-size parameter and the layer dimension that bounds it.
//! Binding every knob to a concrete value yields one [`Dataflow`] — one
//! *mapping* of the style — via [`StyleTemplate::instantiate`]; the
//! enumeration of all legal bindings for a layer shape lives in
//! [`super::tiling`].
//!
//! Knob defaults are the Table 3 bindings (KC-P's 64-wide C cluster,
//! YR-P's 2x2 C/K tiles, YX-P's 8-wide X tile), so
//! [`StyleTemplate::instantiate_defaults`] reproduces the fixed
//! evaluation styles structurally (pinned by tests here and in
//! `ir::styles`). C-P and X-P declare no knobs — Table 3 gives them no
//! tile parameters — and instantiate to exactly one mapping each.

use std::fmt;

use crate::ir::dataflow::Dataflow;
use crate::ir::dims::Dim;
use crate::ir::styles;

/// How candidate tile sizes for a knob are generated from the extent of
/// its layer dimension (see [`super::tiling::tile_values`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileRule {
    /// Divisors of the extent: edge-free tilings (every tile full).
    Divisors,
    /// Geometric cover: powers of two up to the extent, plus the extent
    /// itself (tilings with a partial edge tile).
    Cover,
    /// The union of both (the default for every Table 3 knob).
    DivisorsAndCover,
}

/// One declared tileable knob of a style template.
#[derive(Debug, Clone, Copy)]
pub struct TileKnob {
    /// Knob name as it appears in instantiated dataflow names
    /// (`KC-P(ct=64)`).
    pub name: &'static str,
    /// The layer dimension whose extent bounds this knob's values.
    pub dim: Dim,
    /// Candidate-value generation rule.
    pub rule: TileRule,
    /// The Table 3 binding. Always included in enumerations (even when
    /// it exceeds the layer's extent — the fixed style uses it
    /// regardless, and resolution clamps), so the enumerated space is a
    /// superset of the fixed evaluation style whenever that style maps.
    pub default: u64,
}

/// A dataflow style with declared tileable knobs and a builder from
/// concrete knob values.
#[derive(Clone)]
pub struct StyleTemplate {
    /// Family name (matches the DSE family spellings: `kc-p`, ...).
    pub name: &'static str,
    /// Declared knobs, in builder-argument order.
    pub knobs: Vec<TileKnob>,
    build: fn(&[u64]) -> Dataflow,
}

impl fmt::Debug for StyleTemplate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("StyleTemplate")
            .field("name", &self.name)
            .field("knobs", &self.knobs)
            .finish()
    }
}

impl StyleTemplate {
    /// Bind every knob to a value, producing one concrete mapping of
    /// this style. `values` must match the declared knob count.
    pub fn instantiate(&self, values: &[u64]) -> Dataflow {
        assert_eq!(
            values.len(),
            self.knobs.len(),
            "template '{}' declares {} knob(s), got {} value(s)",
            self.name,
            self.knobs.len(),
            values.len()
        );
        (self.build)(values)
    }

    /// Instantiate at the Table 3 default bindings (the fixed
    /// evaluation style of this family, structurally).
    pub fn instantiate_defaults(&self) -> Dataflow {
        let defaults: Vec<u64> = self.knobs.iter().map(|k| k.default).collect();
        self.instantiate(&defaults)
    }

    /// Instantiate the full grid of explicit per-knob value lists, in
    /// odometer order (last knob fastest). This is the compatibility
    /// path behind the hand-coded DSE variant lists: no filtering, no
    /// dedup — exactly the listed combinations, in exactly their nested
    /// loop order.
    pub fn instantiate_grid(&self, values_per_knob: &[&[u64]]) -> Vec<Dataflow> {
        assert_eq!(values_per_knob.len(), self.knobs.len(), "template '{}'", self.name);
        if values_per_knob.is_empty() {
            return vec![self.instantiate(&[])];
        }
        let mut out = Vec::new();
        let mut combo: Vec<u64> = values_per_knob.iter().map(|axis| axis[0]).collect();
        let mut idx = vec![0usize; values_per_knob.len()];
        loop {
            out.push(self.instantiate(&combo));
            // Odometer step, last knob fastest.
            let mut k = values_per_knob.len();
            loop {
                if k == 0 {
                    return out;
                }
                k -= 1;
                idx[k] += 1;
                if idx[k] < values_per_knob[k].len() {
                    combo[k] = values_per_knob[k][idx[k]];
                    break;
                }
                idx[k] = 0;
                combo[k] = values_per_knob[k][0];
            }
        }
    }

    /// C-Partitioned (Table 3 row 1): no tile knobs.
    pub fn c_p() -> StyleTemplate {
        StyleTemplate { name: "c-p", knobs: vec![], build: |_| styles::c_p() }
    }

    /// X-Partitioned (Table 3 row 2): no tile knobs.
    pub fn x_p() -> StyleTemplate {
        StyleTemplate { name: "x-p", knobs: vec![], build: |_| styles::x_p() }
    }

    /// YX-Partitioned (Table 3 row 3): X tile / cluster width knob.
    pub fn yx_p() -> StyleTemplate {
        StyleTemplate {
            name: "yx-p",
            knobs: vec![TileKnob { name: "xt", dim: Dim::X, rule: TileRule::DivisorsAndCover, default: 8 }],
            build: |v| styles::yx_p_xt(v[0]),
        }
    }

    /// YR-Partitioned (Table 3 row 4): C and K tile knobs.
    pub fn yr_p() -> StyleTemplate {
        StyleTemplate {
            name: "yr-p",
            knobs: vec![
                TileKnob { name: "c", dim: Dim::C, rule: TileRule::DivisorsAndCover, default: 2 },
                TileKnob { name: "k", dim: Dim::K, rule: TileRule::DivisorsAndCover, default: 2 },
            ],
            build: |v| styles::yr_p_ck(v[0], v[1]),
        }
    }

    /// KC-Partitioned (Table 3 row 5): C tile / cluster size knob.
    pub fn kc_p() -> StyleTemplate {
        StyleTemplate {
            name: "kc-p",
            knobs: vec![TileKnob { name: "ct", dim: Dim::C, rule: TileRule::DivisorsAndCover, default: 64 }],
            build: |v| styles::kc_p_ct(v[0]),
        }
    }

    /// The five Table 3 style templates, in the paper's order.
    pub fn all() -> Vec<StyleTemplate> {
        vec![
            StyleTemplate::c_p(),
            StyleTemplate::x_p(),
            StyleTemplate::yx_p(),
            StyleTemplate::yr_p(),
            StyleTemplate::kc_p(),
        ]
    }

    /// Look a template up by (case-insensitive) family name.
    pub fn by_name(name: &str) -> Option<StyleTemplate> {
        match name.to_ascii_lowercase().replace('_', "-").as_str() {
            "c-p" | "cp" => Some(StyleTemplate::c_p()),
            "x-p" | "xp" => Some(StyleTemplate::x_p()),
            "yx-p" | "yxp" => Some(StyleTemplate::yx_p()),
            "yr-p" | "yrp" => Some(StyleTemplate::yr_p()),
            "kc-p" | "kcp" => Some(StyleTemplate::kc_p()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_reproduce_the_fixed_table3_styles() {
        for t in StyleTemplate::all() {
            let fixed = styles::by_name(t.name).expect("every template names a style");
            assert_eq!(
                t.instantiate_defaults().fingerprint(),
                fixed.fingerprint(),
                "{}: the default binding must be the Table 3 style",
                t.name
            );
        }
    }

    #[test]
    fn grid_instantiation_is_odometer_order_last_knob_fastest() {
        let yr = StyleTemplate::yr_p();
        let grid = yr.instantiate_grid(&[&[1, 2], &[4, 8]]);
        let names: Vec<&str> = grid.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(names, vec!["YR-P(c=1,k=4)", "YR-P(c=1,k=8)", "YR-P(c=2,k=4)", "YR-P(c=2,k=8)"]);
        // Knobless templates instantiate to exactly one mapping.
        let cp = StyleTemplate::c_p().instantiate_grid(&[]);
        assert_eq!(cp.len(), 1);
        assert_eq!(cp[0].fingerprint(), styles::c_p().fingerprint());
    }

    #[test]
    fn by_name_matches_family_spellings() {
        for t in StyleTemplate::all() {
            assert_eq!(StyleTemplate::by_name(t.name).unwrap().name, t.name);
        }
        assert!(StyleTemplate::by_name("zz-p").is_none());
    }

    #[test]
    #[should_panic(expected = "declares 1 knob")]
    fn instantiate_checks_arity() {
        StyleTemplate::kc_p().instantiate(&[]);
    }
}
