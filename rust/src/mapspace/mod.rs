//! The mapping-space subsystem: programmatic per-layer tiling
//! generation over the Table 3 dataflow styles, and a layer-wise mapper
//! that searches it.
//!
//! The paper's central observation (§2, §5) is that data-centric
//! directives describe a *space* of mappings and that the right mapping
//! depends on the layer shape. Before this subsystem, the DSE drew its
//! dataflow axis from hand-coded lists of ~5 tile bindings per style;
//! now the space is generated:
//!
//! * [`template`] — [`StyleTemplate`]: each Table 3 style with its
//!   tileable dimensions *declared* ([`TileKnob`]: dim, value rule,
//!   Table 3 default). Binding knobs yields concrete [`Dataflow`]s; the
//!   defaults reproduce the fixed evaluation styles structurally.
//! * [`tiling`] — deterministic enumeration of legal bindings per layer
//!   shape: per-knob candidate tile sizes (divisors + power-of-two
//!   covers, capped at a per-dim `resolution`, default always kept),
//!   the odometer product over knobs, `Dataflow::resolve` validation
//!   (every emitted candidate maps), and fingerprint dedup. Also
//!   [`tile_adjacency`], the one-tile-step neighbor relation the guided
//!   DSE strategy uses on mapspace-backed variant axes.
//! * [`mapper`] — [`Mapper`]: per unique layer shape, a
//!   [`SearchBudget`](crate::dse::strategy::SearchBudget)-governed
//!   search of the enumeration for the best mapping under an
//!   [`Objective`](crate::engine::analysis::Objective), evaluated
//!   through the shape-memoized `Analyzer` (sharable via
//!   [`SharedStore`](crate::cache::SharedStore) / `--cache-file`).
//!   Surfaced as the `maestro map` CLI subcommand.
//!
//! The DSE variant axis is mapspace-backed: `dse::space`'s
//! `kc_p_variants`/`yr_p_variants`/`yx_p_variants` instantiate the
//! templates at the legacy value grids (bit-identical to the hand-coded
//! lists — the fig13/ci_smoke pins hold), and
//! `DesignSpace::mapspace` builds a variant axis by enumeration, with
//! tile-coordinate adjacency driving the guided strategy's
//! neighborhoods.
//!
//! [`Dataflow`]: crate::ir::dataflow::Dataflow

pub mod mapper;
pub mod template;
pub mod tiling;

pub use mapper::{
    MapChunk, MapCtx, MapDriver, MapWave, Mapper, MapperConfig, MapperStats, MappingOutcome,
    ShapeMapping,
};
pub use template::{StyleTemplate, TileKnob, TileRule};
pub use tiling::{enumerate, enumerate_all, enumerate_defaults, tile_adjacency, tile_values, Enumeration};
