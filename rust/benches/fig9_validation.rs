//! Fig 9 — runtime model validation.
//!
//! Paper: MAESTRO's estimated runtime vs MAERI RTL simulation (VGG16,
//! 64 PEs) and Eyeriss's reported delay (AlexNet, 168 PEs), within 3.9%
//! average absolute error.
//!
//! Here: analytical engine vs the cycle-level schedule simulator (the
//! RTL substitute, DESIGN.md §4). Late VGG layers are channel-scaled
//! 1/8 to keep the step-walking ground truth tractable in bench time —
//! the relative-error metric is unaffected (both models see the same
//! layer).

use std::time::Instant;

use maestro::engine::analysis::analyze_layer;
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::model::layer::Layer;
use maestro::model::zoo::{alexnet, vgg16};
use maestro::sim::cycle::simulate;
use maestro::util::benchkit::section;
use maestro::util::table::{num, Table};

/// Scale channel dims down to keep the simulator walk below ~2M steps.
fn scaled(l: &Layer) -> Layer {
    let mut l = l.clone();
    while l.c * l.k > 64 * 64 {
        if l.c >= l.k && l.c >= 16 {
            l.c /= 2;
        } else if l.k >= 16 {
            l.k /= 2;
        } else {
            break;
        }
    }
    l
}

fn validate(name: &str, layers: &[Layer], hw: &HwConfig, df_name: &str) {
    let df = styles::by_name(df_name).unwrap();
    section(&format!("Fig 9 [{name}]: MAESTRO vs cycle-sim, {} PEs, {}", hw.num_pes, df.name));
    let mut t = Table::new(&["layer", "sim cycles", "model cycles", "err %", "sim ms", "model us", "speedup"]);
    let mut errs = Vec::new();
    let mut speedups = Vec::new();
    for layer in layers {
        let layer = scaled(layer);
        let t0 = Instant::now();
        let sim = match simulate(&layer, &df, hw, 60_000_000) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("  {}: sim skipped ({e})", layer.name);
                continue;
            }
        };
        let sim_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = Instant::now();
        let ana = analyze_layer(&layer, &df, hw).unwrap();
        let model_us = t1.elapsed().as_secs_f64() * 1e6;
        let err = (ana.runtime - sim.cycles).abs() / sim.cycles * 100.0;
        errs.push(err);
        let speedup = sim_ms * 1e3 / model_us.max(1e-9);
        speedups.push(speedup);
        t.row(&[
            layer.name.clone(),
            num(sim.cycles),
            num(ana.runtime),
            format!("{err:.2}"),
            format!("{sim_ms:.1}"),
            format!("{model_us:.0}"),
            format!("{speedup:.0}x"),
        ]);
    }
    print!("{}", t.render());
    let avg = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
    let avg_speedup = speedups.iter().sum::<f64>() / speedups.len().max(1) as f64;
    println!("average |error| = {avg:.2}%   (paper: 3.9% vs RTL)");
    println!("average model-vs-sim speedup = {avg_speedup:.0}x (paper: 1029-4116x vs RTL)");
}

fn main() {
    // MAERI-like: VGG16 conv stack on 64 PEs, row-stationary (YR-P).
    validate("MAERI/VGG16", &vgg16::conv_only().layers, &HwConfig::maeri_64(), "yr-p");
    // Eyeriss: AlexNet conv stack on 168 PEs, row-stationary.
    validate("Eyeriss/AlexNet", &alexnet::conv_only().layers, &HwConfig::eyeriss_168(), "yr-p");
    // Cross-dataflow robustness: X-P and KC-P on a mid VGG layer.
    let mid = vec![vgg16::conv_only().layers[4].clone()];
    validate("cross-check/X-P", &mid, &HwConfig::maeri_64(), "x-p");
    validate("cross-check/KC-P", &mid, &HwConfig::fig10_default(), "kc-p");
}
