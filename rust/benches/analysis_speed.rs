//! Analysis speed — the paper's §4.5 claim: MAESTRO runs in ~10 ms per
//! (layer, dataflow) vs 7.2-28.8 hours of RTL simulation (1029-4116x).
//!
//! Measures per-layer analysis latency across the Table 3 dataflows and
//! the VGG16 conv stack, the shape-memoized `Analyzer` against the
//! uncached per-layer loop on a repeated-shape zoo network (ResNet-50),
//! and the analytic-vs-simulator speedup on a bounded layer.
//!
//! CI smoke mode: `ANALYSIS_SMOKE=1 cargo bench --bench analysis_speed`
//! runs the cached-vs-uncached comparison, a cache-file warm-start
//! round trip (cold analyze -> flush -> fresh store load -> warm
//! analyze), and the two-phase-vs-monolithic bandwidth-axis comparison
//! (one `ReuseProfile` build + 9 `finalize` replays vs 9 fresh
//! analyses; the profiled path must not be slower), and writes the
//! layers/s + hit/miss + warm-vs-cold + `profile_vs_monolithic` record
//! to `BENCH_analysis_rate.json` (override with `ANALYSIS_SMOKE_OUT`)
//! — uploaded as a CI build artifact next to `BENCH_dse_rate.json`.

use std::sync::Arc;

use maestro::cache::SharedStore;
use maestro::dse::space::bandwidth_axis;
use maestro::engine::analysis::{analyze_layer, Analyzer};
use maestro::engine::profile::ReuseProfile;
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::model::layer::Layer;
use maestro::model::network::Network;
use maestro::model::zoo::{self, vgg16};
use maestro::sim::cycle::simulate;
use maestro::util::benchkit::{bench, bench_throughput, section};

/// Cached-vs-uncached whole-network analysis throughput on a
/// repeated-shape network. Returns (uncached layers/s, cached layers/s,
/// hits, misses) for `repeats` passes over the network.
fn cached_vs_uncached(net: &Network, hw: &HwConfig, repeats: u32) -> (f64, f64, u64, u64) {
    let df = styles::kc_p();
    // Uncached: the pre-Analyzer per-layer loop.
    let t0 = std::time::Instant::now();
    for _ in 0..repeats {
        for layer in &net.layers {
            let _ = analyze_layer(layer, &df, hw);
        }
    }
    let uncached_s = t0.elapsed().as_secs_f64();
    // Cached: one Analyzer across all passes — each unique shape is
    // analyzed once, everything else replays.
    let mut analyzer = Analyzer::new();
    let t1 = std::time::Instant::now();
    for _ in 0..repeats {
        for layer in &net.layers {
            let _ = analyzer.analyze(layer, &df, hw);
        }
    }
    let cached_s = t1.elapsed().as_secs_f64();
    let total = (net.layers.len() as u64 * repeats as u64) as f64;
    (total / uncached_s.max(1e-9), total / cached_s.max(1e-9), analyzer.cache_hits(), analyzer.cache_misses())
}

/// Cache-file warm start on `net`: analyze cold through a fresh
/// SharedStore (timed), flush to a temp cache file, reload into another
/// fresh store ("a new process"), and re-analyze warm (timed). Returns
/// (cold_s, warm_s, disk_hits, records_loaded).
fn warm_vs_cold(net: &Network, hw: &HwConfig) -> (f64, f64, u64, usize) {
    let df = styles::kc_p();
    let path = std::env::temp_dir().join(format!("maestro_bench_warm_{}.mcache", std::process::id()));
    let _ = std::fs::remove_file(&path);

    let cold_store = Arc::new(SharedStore::new());
    let mut cold = Analyzer::with_store(Arc::clone(&cold_store));
    let t0 = std::time::Instant::now();
    for layer in &net.layers {
        let _ = cold.analyze(layer, &df, hw);
    }
    let cold_s = t0.elapsed().as_secs_f64();
    cold_store.flush(&path).expect("flush bench cache file");

    let warm_store = Arc::new(SharedStore::new());
    let loaded = warm_store.load(&path);
    assert!(loaded.warning.is_none(), "bench cache file must round-trip: {:?}", loaded.warning);
    let mut warm = Analyzer::with_store(Arc::clone(&warm_store));
    let t1 = std::time::Instant::now();
    for layer in &net.layers {
        let _ = warm.analyze(layer, &df, hw);
    }
    let warm_s = t1.elapsed().as_secs_f64();
    let _ = std::fs::remove_file(&path);
    assert!(warm.disk_hits() > 0, "warm pass must hit disk-loaded entries");
    (cold_s, warm_s, warm.disk_hits(), loaded.loaded)
}

/// Two-phase vs monolithic analysis across the canonical 9-point
/// bandwidth axis. The monolithic path runs a fresh [`analyze_layer`]
/// per (layer, bandwidth) design; the profiled path resolves and builds
/// one [`ReuseProfile`] per layer, then replays `finalize` per
/// bandwidth point. Both evaluate the same designs in the same order;
/// failures (if any) fail identically on both paths, so the design
/// count stays comparable. Returns (monolithic designs/s, profiled
/// designs/s, designs per pass, axis length).
fn profile_vs_monolithic(net: &Network, hw: &HwConfig, repeats: u32) -> (f64, f64, u64, usize) {
    let df = styles::kc_p();
    let axis = bandwidth_axis(9);
    let designs = net.layers.len() as u64 * axis.len() as u64;

    let t0 = std::time::Instant::now();
    for _ in 0..repeats {
        for layer in &net.layers {
            for &bw in &axis {
                let h = HwConfig { noc_bandwidth: bw, ..hw.clone() };
                std::hint::black_box(analyze_layer(layer, &df, &h).ok());
            }
        }
    }
    let mono_s = t0.elapsed().as_secs_f64();

    let t1 = std::time::Instant::now();
    for _ in 0..repeats {
        for layer in &net.layers {
            let profile = df
                .resolve(layer, hw.num_pes)
                .and_then(|r| ReuseProfile::build(layer, &r, hw));
            let Ok(profile) = profile else { continue };
            for &bw in &axis {
                let h = HwConfig { noc_bandwidth: bw, ..hw.clone() };
                std::hint::black_box(profile.finalize(&h));
            }
        }
    }
    let prof_s = t1.elapsed().as_secs_f64();

    let total = (designs * repeats as u64) as f64;
    (total / mono_s.max(1e-9), total / prof_s.max(1e-9), designs, axis.len())
}

fn analysis_rate_json(
    net: &Network,
    rates: (f64, f64, u64, u64),
    warm: (f64, f64, u64, usize),
    pvm: (f64, f64, u64, usize),
) -> String {
    let (uncached, cached, hits, misses) = rates;
    let (cold_s, warm_s, disk_hits, records) = warm;
    let (mono_rate, prof_rate, designs, axis_len) = pvm;
    format!(
        "{{\n  \"bench\": \"analysis_rate\",\n  \"network\": \"{}\",\n  \"dataflow\": \"KC-P\",\n  \
         \"layers\": {},\n  \"unique_shapes\": {},\n  \"uncached_layers_per_s\": {uncached:.1},\n  \
         \"cached_layers_per_s\": {cached:.1},\n  \"speedup\": {:.2},\n  \"cache_hits\": {hits},\n  \
         \"cache_misses\": {misses},\n  \"warm_start\": {{\n    \"cold_seconds\": {cold_s:.6},\n    \
         \"warm_seconds\": {warm_s:.6},\n    \"speedup\": {:.2},\n    \"disk_hits\": {disk_hits},\n    \
         \"records_loaded\": {records}\n  }},\n  \"profile_vs_monolithic\": {{\n    \
         \"bandwidth_points\": {axis_len},\n    \"designs_per_pass\": {designs},\n    \
         \"monolithic_designs_per_s\": {mono_rate:.1},\n    \
         \"profiled_designs_per_s\": {prof_rate:.1},\n    \"speedup\": {:.2}\n  }}\n}}\n",
        net.name,
        net.layers.len(),
        net.unique_shapes().len(),
        cached / uncached.max(1e-9),
        cold_s / warm_s.max(1e-9),
        prof_rate / mono_rate.max(1e-9),
    )
}

fn main() {
    let hw = HwConfig::fig10_default();

    let smoke = std::env::var("ANALYSIS_SMOKE")
        .map(|v| matches!(v.as_str(), "1" | "true" | "TRUE"))
        .unwrap_or(false);
    if smoke {
        section("analysis bench smoke (CI): cached vs uncached layers/s + warm start on resnet50");
        let net = zoo::by_name("resnet50").unwrap();
        let rates = cached_vs_uncached(&net, &hw, 3);
        let warm = warm_vs_cold(&net, &hw);
        let pvm = profile_vs_monolithic(&net, &hw, 2);
        assert!(
            pvm.1 >= pvm.0,
            "two-phase bandwidth axis must be at least as fast as monolithic: \
             profiled {:.1} designs/s < monolithic {:.1} designs/s",
            pvm.1,
            pvm.0
        );
        let json = analysis_rate_json(&net, rates, warm, pvm);
        print!("{json}");
        let path = std::env::var("ANALYSIS_SMOKE_OUT").unwrap_or_else(|_| "BENCH_analysis_rate.json".into());
        std::fs::write(&path, json).expect("write analysis smoke json");
        println!("wrote {path}");
        return;
    }

    section("analysis latency per (layer, dataflow) — paper: ~10 ms");
    for df in styles::all_styles() {
        let layer = vgg16::conv13();
        if analyze_layer(&layer, &df, &hw).is_err() {
            continue;
        }
        bench(&format!("analyze vgg16-conv13 under {}", df.name), 3, 25, || {
            analyze_layer(&layer, &df, &hw).unwrap().runtime
        });
    }

    section("whole-network analysis throughput");
    let net = vgg16::conv_only();
    bench_throughput("analyze 13 VGG16 conv layers (KC-P)", 13, 2, 10, || {
        let mut acc = 0.0;
        for l in &net.layers {
            acc += analyze_layer(l, &styles::kc_p(), &hw).unwrap().runtime;
        }
        acc
    });

    section("shape-memoized Analyzer vs uncached loop (repeated-shape networks)");
    for name in ["resnet50", "vgg16-conv", "mobilenetv2"] {
        let net = zoo::by_name(name).unwrap();
        let (uncached, cached, hits, misses) = cached_vs_uncached(&net, &hw, 5);
        println!(
            "{name}: {} layers / {} unique shapes | uncached {uncached:.0} layers/s | \
             cached {cached:.0} layers/s | speedup x{:.2} | cache {hits}h/{misses}m",
            net.layers.len(),
            net.unique_shapes().len(),
            cached / uncached.max(1e-9),
        );
    }

    section("two-phase profiles vs monolithic re-analysis across the bandwidth axis");
    for name in ["resnet50", "vgg16-conv"] {
        let net = zoo::by_name(name).unwrap();
        let (mono, prof, designs, points) = profile_vs_monolithic(&net, &hw, 3);
        println!(
            "{name}: {designs} designs/pass ({points}-point bw axis) | monolithic {mono:.0} designs/s | \
             profiled {prof:.0} designs/s | speedup x{:.2}",
            prof / mono.max(1e-9),
        );
    }

    section("cache-file warm start (cold analyze -> flush -> fresh load -> warm analyze)");
    for name in ["resnet50", "vgg16-conv"] {
        let net = zoo::by_name(name).unwrap();
        let (cold_s, warm_s, disk_hits, records) = warm_vs_cold(&net, &hw);
        println!(
            "{name}: cold {cold_s:.4}s | warm {warm_s:.4}s (x{:.1}) | {records} records on disk, {disk_hits} disk hits",
            cold_s / warm_s.max(1e-9)
        );
    }

    section("analytic model vs cycle-level simulator (RTL substitute)");
    let layer = Layer::conv2d("cmp", 1, 32, 32, 34, 34, 3, 3, 1);
    let h64 = HwConfig::maeri_64();
    let t0 = std::time::Instant::now();
    let sim = simulate(&layer, &styles::x_p(), &h64, 100_000_000).unwrap();
    let sim_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let ana = analyze_layer(&layer, &styles::x_p(), &h64).unwrap();
    let ana_s = t1.elapsed().as_secs_f64();
    println!(
        "simulator: {:.3}s ({} steps) | analytic: {:.6}s | speedup {:.0}x (paper: 1029-4116x vs RTL) | runtime err {:.2}%",
        sim_s,
        sim.steps,
        ana_s,
        sim_s / ana_s.max(1e-9),
        (ana.runtime - sim.cycles).abs() / sim.cycles * 100.0
    );
}
