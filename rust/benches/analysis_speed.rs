//! Analysis speed — the paper's §4.5 claim: MAESTRO runs in ~10 ms per
//! (layer, dataflow) vs 7.2-28.8 hours of RTL simulation (1029-4116x).
//!
//! Measures per-layer analysis latency across the Table 3 dataflows and
//! the VGG16 conv stack, and the analytic-vs-simulator speedup on a
//! bounded layer.

use maestro::engine::analysis::analyze_layer;
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::model::layer::Layer;
use maestro::model::zoo::vgg16;
use maestro::sim::cycle::simulate;
use maestro::util::benchkit::{bench, bench_throughput, section};

fn main() {
    let hw = HwConfig::fig10_default();

    section("analysis latency per (layer, dataflow) — paper: ~10 ms");
    for df in styles::all_styles() {
        let layer = vgg16::conv13();
        if analyze_layer(&layer, &df, &hw).is_err() {
            continue;
        }
        bench(&format!("analyze vgg16-conv13 under {}", df.name), 3, 25, || {
            analyze_layer(&layer, &df, &hw).unwrap().runtime
        });
    }

    section("whole-network analysis throughput");
    let net = vgg16::conv_only();
    bench_throughput("analyze 13 VGG16 conv layers (KC-P)", 13, 2, 10, || {
        let mut acc = 0.0;
        for l in &net.layers {
            acc += analyze_layer(l, &styles::kc_p(), &hw).unwrap().runtime;
        }
        acc
    });

    section("analytic model vs cycle-level simulator (RTL substitute)");
    let layer = Layer::conv2d("cmp", 1, 32, 32, 34, 34, 3, 3, 1);
    let h64 = HwConfig::maeri_64();
    let t0 = std::time::Instant::now();
    let sim = simulate(&layer, &styles::x_p(), &h64, 100_000_000).unwrap();
    let sim_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let ana = analyze_layer(&layer, &styles::x_p(), &h64).unwrap();
    let ana_s = t1.elapsed().as_secs_f64();
    println!(
        "simulator: {:.3}s ({} steps) | analytic: {:.6}s | speedup {:.0}x (paper: 1029-4116x vs RTL) | runtime err {:.2}%",
        sim_s,
        sim.steps,
        ana_s,
        sim_s / ana_s.max(1e-9),
        (ana.runtime - sim.cycles).abs() / sim.cycles * 100.0
    );
}
