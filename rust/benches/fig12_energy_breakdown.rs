//! Fig 12 — energy breakdown (MAC / L1 / L2 scratchpad) of the Table 3
//! dataflows, normalized to C-P's MAC energy, on the four
//! representative operators.
//!
//! Paper shape: L2 access energy dominates for low-reuse dataflows
//! (C-P); YR-P/KC-P keep L2 energy small through reuse; MAC energy is
//! constant across dataflows for a fixed operator.

use maestro::engine::analysis::analyze_layer;
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::model::zoo::{mobilenet_v2, resnet50, vgg16};
use maestro::util::benchkit::section;
use maestro::util::table::Table;

fn main() {
    let hw = HwConfig::fig10_default();
    let operators = vec![
        ("early (ResNet50 CONV1)", resnet50::conv1()),
        ("late (VGG16 CONV13)", vgg16::conv13()),
        ("DWCONV (MobileNetV2)", mobilenet_v2::dwconv_exemplar()),
        ("PWCONV (MobileNetV2)", mobilenet_v2::bottleneck1_pw()),
    ];

    for (name, layer) in operators {
        section(&format!("Fig 12 [{name}]: energy breakdown, normalized to C-P MAC energy"));
        // C-P MAC energy as the normalizer (the paper's convention).
        let Ok(cp) = analyze_layer(&layer, &styles::c_p(), &hw) else {
            println!("  C-P unmappable on this operator; skipping");
            continue;
        };
        let norm = cp.energy.mac.max(1e-12);
        let mut t = Table::new(&["dataflow", "MAC", "L1", "L2", "NoC", "total"]);
        for df in styles::all_styles() {
            let Ok(s) = analyze_layer(&layer, &df, &hw) else { continue };
            t.row(&[
                df.name.clone(),
                format!("{:.2}", s.energy.mac / norm),
                format!("{:.2}", s.energy.l1 / norm),
                format!("{:.2}", s.energy.l2 / norm),
                format!("{:.2}", s.energy.noc / norm),
                format!("{:.2}", s.energy.total() / norm),
            ]);
        }
        print!("{}", t.render());
    }

    // Shape summary: C-P should pay the most L2 energy on the late layer.
    let late = vgg16::conv13();
    let mut l2: Vec<(String, f64)> = styles::all_styles()
        .iter()
        .filter_map(|df| analyze_layer(&late, df, &hw).ok().map(|s| (df.name.clone(), s.energy.l2)))
        .collect();
    l2.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!(
        "\nshape check [late layer]: highest L2 energy = {} (paper: C-P, 'no local reuse')",
        l2.first().map(|x| x.0.as_str()).unwrap_or("?")
    );
}
