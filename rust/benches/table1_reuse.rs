//! Table 1 — reuse opportunities by (spatially mapped dim x innermost
//! temporally mapped dim), generated from the reuse-analysis rules and
//! printed in the paper's layout. The unit test
//! `engine::reuse::tests::table1_matches_paper_conv2d` asserts the key
//! cells; this bench renders the full table.

use maestro::engine::reuse::{table1, Opportunity};
use maestro::model::layer::Layer;
use maestro::util::benchkit::{bench, section};
use maestro::util::table::Table;

fn sym(o: Opportunity) -> &'static str {
    match o {
        Opportunity::Multicast => "Multicast",
        Opportunity::Reduction => "Reduction",
        Opportunity::None => "-",
    }
}

fn main() {
    section("Table 1: reuse opportunities (CONV2D coupling; F=filter, I=input, O=output)");
    let layer = Layer::conv2d("ref", 1, 64, 64, 58, 58, 3, 3, 1);
    let rows = table1(&layer);
    let mut t = Table::new(&["spatial dim", "innermost temporal", "sp.F", "sp.I", "sp.O", "tm.F", "tm.I", "tm.O"]);
    for r in &rows {
        t.row(&[
            r.spatial_dim.to_string(),
            r.innermost_temporal.to_string(),
            sym(r.spatial[0]).into(),
            sym(r.spatial[1]).into(),
            sym(r.spatial[2]).into(),
            sym(r.temporal[0]).into(),
            sym(r.temporal[1]).into(),
            sym(r.temporal[2]).into(),
        ]);
    }
    print!("{}", t.render());

    // Depthwise comparison: output couples C, flipping the C rows.
    section("Table 1 variant: depthwise coupling (output couples C)");
    let dw = Layer::depthwise("dw", 1, 64, 58, 58, 3, 3, 1);
    let rows = table1(&dw);
    let c_row = rows.iter().find(|r| r.spatial_dim == maestro::ir::dims::Dim::C).unwrap();
    println!(
        "spatial C on depthwise: output {} (dense conv: Reduction)",
        sym(c_row.spatial[2])
    );

    bench("table1 generation", 2, 20, || table1(&layer).len());
}
