//! Fig 13 — the DSE design space of KC-P and YR-P accelerators on an
//! early (VGG16 CONV2) and a late (VGG16 CONV13) layer, under the
//! Eyeriss chip budget (16 mm², 450 mW): area/buffer vs throughput
//! scatters, throughput-/energy-optimized points, and (c) the sweep
//! statistics (designs, valid designs, DSE rate).

use maestro::dse::engine::{sweep, SweepConfig};
use maestro::dse::pareto::{best, Optimize};
use maestro::dse::space::DesignSpace;
use maestro::model::network::Network;
use maestro::model::zoo::vgg16;
use maestro::report::experiments::{buffer_scatter, compare_optima, design_space_scatter};
use maestro::util::benchkit::section;
use maestro::util::table::Table;

fn main() {
    let layers = [("VGG16-CONV2 (early)", vgg16::conv2()), ("VGG16-CONV13 (late)", vgg16::conv13())];
    let mut stats_rows = Table::new(&[
        "family", "layer", "designs", "evaluated", "valid", "pruned", "unmappable", "secs", "rate (designs/s)",
    ]);
    // keep_all_points: the figure needs the full scatter, and this
    // space is small enough to hold.
    let cfg = SweepConfig { keep_all_points: true, ..SweepConfig::default() };

    for family in ["kc-p", "yr-p"] {
        for (lname, layer) in &layers {
            section(&format!("Fig 13: {family} on {lname}, budget 16 mm2 / 450 mW"));
            let space = DesignSpace::fig13(family, 14);
            let out = sweep(&Network::single(layer.clone()), &space, 2, &cfg).unwrap();
            let (points, stats) = (out.points, out.stats);
            let macs = layer.macs() as f64;
            print!("{}", design_space_scatter(&points, macs, &format!("{family} {lname}: area vs throughput")));
            print!("{}", buffer_scatter(&points, macs, &format!("{family} {lname}: buffer vs throughput")));
            println!("pareto front (runtime vs energy): {} points of {} valid", out.frontier.len(), stats.valid);
            if let Some(t) = best(&points, Optimize::Throughput, macs) {
                println!(
                    "  throughput-opt *: pes={} bw={} L1={}el L2={}el area={:.2}mm2 power={:.0}mW thrpt={:.1} MAC/cyc [{}]",
                    t.pes, t.bandwidth, t.l1, t.l2, t.area_mm2, t.power_mw, t.throughput(macs), t.dataflow
                );
            }
            if let Some(e) = best(&points, Optimize::Energy, macs) {
                println!(
                    "  energy-opt     +: pes={} bw={} L1={}el L2={}el area={:.2}mm2 power={:.0}mW energy={:.1}uJ [{}]",
                    e.pes, e.bandwidth, e.l1, e.l2, e.area_mm2, e.power_mw, e.energy_pj / 1e6, e.dataflow
                );
            }
            if let Some(c) = compare_optima(&points, macs) {
                println!(
                    "  energy-opt vs throughput-opt: power x{:.2} (paper 2.16x on CONV11), SRAM x{:.1} (paper 10.6x), PEs {:.0}% (paper 80%), EDP -{:.0}% (paper 65%), throughput {:.0}% (paper 62%)",
                    c.power_ratio, c.sram_ratio, c.pe_ratio * 100.0, c.edp_improvement * 100.0, c.throughput_fraction * 100.0
                );
            }
            stats_rows.row(&[
                family.to_string(),
                lname.to_string(),
                stats.total_designs.to_string(),
                stats.evaluated.to_string(),
                stats.valid.to_string(),
                stats.pruned.to_string(),
                stats.unmappable.to_string(),
                format!("{:.2}", stats.seconds),
                format!("{:.0}", stats.rate()),
            ]);
        }
    }

    // The intro's CONV11 KC-P example.
    section("Intro headline: KC-P on VGG16 CONV11");
    let conv11 = vgg16::conv11();
    let space = DesignSpace::fig13("kc-p", 14);
    let points = sweep(&Network::single(conv11.clone()), &space, 2, &cfg).unwrap().points;
    if let Some(c) = compare_optima(&points, conv11.macs() as f64) {
        println!(
            "energy- vs throughput-optimized: power x{:.2} (paper 2.16x), SRAM x{:.1} (paper 10.6x), PEs {:.0}% (paper 80%), EDP improvement {:.0}% (paper 65%), throughput {:.0}% (paper 62%)",
            c.power_ratio, c.sram_ratio, c.pe_ratio * 100.0, c.edp_improvement * 100.0, c.throughput_fraction * 100.0
        );
    }

    section("Fig 13 (c): DSE sweep statistics");
    print!("{}", stats_rows.render());
    println!("(paper: 0.46M-3.3K designs/s per run, 0.17M/s average; see also `cargo bench --bench dse_rate`)");
}
