//! Mapper rate — the layer-wise mapper's candidates/s, serial vs
//! pooled, on a zoo model (PR 7's tentpole measurement, the mapspace
//! counterpart of `dse_rate`'s sweep scaling).
//!
//! CI smoke mode: `MAP_SMOKE=1 cargo bench --bench map_rate` maps the
//! VGG16 conv stack once with `threads = 1` (the serial reference) and
//! once with `threads = 4`, asserts the two outcomes are bit-identical
//! and the pooled run no slower than the serial one, and writes both
//! rates to `BENCH_map.json` (override with `MAP_SMOKE_OUT`) — uploaded
//! as a CI build artifact next to `BENCH_dse_rate.json`.

use maestro::hw::config::HwConfig;
use maestro::mapspace::{Mapper, MapperConfig, MappingOutcome};
use maestro::model::network::Network;
use maestro::model::zoo::vgg16;
use maestro::util::benchkit::section;

/// One cold mapper run at the given thread count.
fn run(net: &Network, hw: &HwConfig, tile_resolution: usize, threads: usize) -> MappingOutcome {
    let cfg = MapperConfig { tile_resolution, threads, ..MapperConfig::default() };
    Mapper::new().map_network(net, hw, &cfg).expect("mapper must map the bench network")
}

fn rate(out: &MappingOutcome) -> f64 {
    out.stats.evaluated as f64 / out.stats.seconds.max(1e-9)
}

/// The determinism contract, checked where it is measured: winners and
/// network bits must not move with the thread count.
fn assert_bit_identical(got: &MappingOutcome, want: &MappingOutcome, ctx: &str) -> bool {
    assert_eq!(got.network.runtime.to_bits(), want.network.runtime.to_bits(), "{ctx}: runtime");
    assert_eq!(
        got.network.energy.total().to_bits(),
        want.network.energy.total().to_bits(),
        "{ctx}: energy"
    );
    assert_eq!(got.per_shape.len(), want.per_shape.len(), "{ctx}: shape count");
    for (g, w) in got.per_shape.iter().zip(&want.per_shape) {
        assert_eq!(g.dataflow, w.dataflow, "{ctx}: winner for {}", w.representative);
    }
    assert_eq!(got.stats.evaluated, want.stats.evaluated, "{ctx}: evaluated");
    assert_eq!(got.stats.budget_skipped, want.stats.budget_skipped, "{ctx}: budget_skipped");
    true
}

fn run_json(threads: usize, out: &MappingOutcome) -> String {
    format!(
        "{{\"threads\": {threads}, \"candidates\": {}, \"evaluated\": {}, \
         \"seconds\": {:.6}, \"candidates_per_s\": {:.1}}}",
        out.stats.candidates,
        out.stats.evaluated,
        out.stats.seconds,
        rate(out),
    )
}

/// CI smoke: serial vs 4-thread cold maps, bit-identity + no-slower
/// assertions, JSON record.
fn run_smoke(net: &Network, hw: &HwConfig) {
    // Heavier than the mapper's default resolution so per-shape searches
    // dominate setup and the pool has real work to amortize its cost.
    let tile_resolution = 8;
    section("map bench smoke (CI): serial vs pooled mapper on the VGG16 conv stack");
    let serial = run(net, hw, tile_resolution, 1);
    let threaded = run(net, hw, tile_resolution, 4);
    println!("threads 1: {}", serial.stats.summary());
    println!("threads 4: {}", threaded.stats.summary());
    let bit_identical = assert_bit_identical(&threaded, &serial, "threads=4 vs serial");
    let speedup = rate(&threaded) / rate(&serial).max(1e-9);
    println!("speedup x{speedup:.2} (candidates/s)");
    assert!(
        rate(&threaded) >= rate(&serial),
        "the pooled mapper must be no slower than serial (serial {:.1}/s, threaded {:.1}/s)",
        rate(&serial),
        rate(&threaded),
    );

    let json = format!(
        "{{\n  \"bench\": \"map_rate\",\n  \"workload\": \"{}\",\n  \
         \"workload_layers\": {},\n  \"workload_unique_shapes\": {},\n  \
         \"tile_resolution\": {tile_resolution},\n  \"runs\": [\n    {},\n    {}\n  ],\n  \
         \"speedup\": {speedup:.4},\n  \"bit_identical\": {bit_identical}\n}}\n",
        net.name,
        net.layers.len(),
        net.unique_shapes().len(),
        run_json(1, &serial),
        run_json(4, &threaded),
    );
    let path = std::env::var("MAP_SMOKE_OUT").unwrap_or_else(|_| "BENCH_map.json".into());
    std::fs::write(&path, json).expect("write map bench json");
    println!("wrote {path}");
}

fn main() {
    let net = vgg16::conv_only();
    let hw = HwConfig::fig10_default();
    let smoke = std::env::var("MAP_SMOKE")
        .map(|v| matches!(v.as_str(), "1" | "true" | "TRUE"))
        .unwrap_or(false);
    if smoke {
        run_smoke(&net, &hw);
        return;
    }

    section("mapper rate: thread scaling (VGG16 conv stack, cold store)");
    let tile_resolution = 8;
    let mut reference: Option<MappingOutcome> = None;
    for threads in [1usize, 2, 4, 8] {
        let out = run(&net, &hw, tile_resolution, threads);
        println!(
            "threads {threads}: {} -> {:.1} candidates/s",
            out.stats.summary(),
            rate(&out)
        );
        if let Some(r) = &reference {
            assert_bit_identical(&out, r, "thread scaling");
            println!("  speedup x{:.2}", rate(&out) / rate(r).max(1e-9));
        } else {
            reference = Some(out);
        }
    }
}
