//! Fig 11 — reuse factors and NoC bandwidth requirements of the Table 3
//! dataflows on the four representative operators (256 PEs):
//! early layer (ResNet50 CONV1), late layer (VGG16 CONV13), DWCONV
//! (MobileNetV2), PWCONV (MobileNetV2 bottleneck expand), plus the
//! algorithmic maximum ("A" bars).
//!
//! Paper shape: YR-P has much higher activation/filter reuse in early
//! layers (5.8x / 15.17x vs KC-P); in late layers YR-P and KC-P reuse
//! factors converge (<11% apart); YX-P needs the most bandwidth on
//! point-wise convolution.

use maestro::engine::analysis::{algorithmic_max_reuse, analyze_layer};
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::model::layer::Layer;
use maestro::model::tensor::TensorKind;
use maestro::model::zoo::{mobilenet_v2, resnet50, vgg16};
use maestro::util::benchkit::section;
use maestro::util::table::Table;

fn operators() -> Vec<(&'static str, Layer)> {
    vec![
        ("early (ResNet50 CONV1)", resnet50::conv1()),
        ("late (VGG16 CONV13)", vgg16::conv13()),
        ("DWCONV (MobileNetV2)", mobilenet_v2::dwconv_exemplar()),
        ("PWCONV (MobileNetV2)", mobilenet_v2::bottleneck1_pw()),
    ]
}

fn main() {
    let hw = HwConfig::fig10_default();

    section("Fig 11 (a): activation (input) reuse factor");
    let mut ta = Table::new(&["operator", "C-P", "X-P", "YX-P", "YR-P", "KC-P", "A (max)"]);
    section_body(&mut ta, &hw, TensorKind::Input);
    print!("{}", ta.render());

    section("Fig 11 (b): filter reuse factor");
    let mut tf = Table::new(&["operator", "C-P", "X-P", "YX-P", "YR-P", "KC-P", "A (max)"]);
    section_body(&mut tf, &hw, TensorKind::Filter);
    print!("{}", tf.render());

    section("Fig 11 (c): NoC bandwidth requirement (elements/cycle)");
    let mut tb = Table::new(&["operator", "C-P", "X-P", "YX-P", "YR-P", "KC-P"]);
    for (name, layer) in operators() {
        let mut row = vec![name.to_string()];
        for df in styles::all_styles() {
            let cell = match analyze_layer(&layer, &df, &hw) {
                Ok(s) => format!("{:.1}", s.peak_bw_need),
                Err(_) => "n/a".into(),
            };
            row.push(cell);
        }
        tb.row(&row);
    }
    print!("{}", tb.render());

    // The paper's headline ratios on the early layer.
    let early = resnet50::conv1();
    let yr = analyze_layer(&early, &styles::yr_p(), &hw);
    let kc = analyze_layer(&early, &styles::kc_p(), &hw);
    if let (Ok(yr), Ok(kc)) = (yr, kc) {
        println!(
            "early-layer reuse ratio YR-P/KC-P: activation {:.1}x (paper 5.8x), filter {:.1}x (paper 15.17x)",
            yr.reuse_factor(TensorKind::Input) / kc.reuse_factor(TensorKind::Input),
            yr.reuse_factor(TensorKind::Filter) / kc.reuse_factor(TensorKind::Filter),
        );
    }
    let late = vgg16::conv13();
    if let (Ok(yr), Ok(kc)) = (
        analyze_layer(&late, &styles::yr_p(), &hw),
        analyze_layer(&late, &styles::kc_p(), &hw),
    ) {
        let d = (yr.reuse_factor(TensorKind::Input) / kc.reuse_factor(TensorKind::Input) - 1.0).abs() * 100.0;
        println!("late-layer YR-P vs KC-P activation reuse difference: {d:.1}% (paper <11%)");
    }
}

fn section_body(t: &mut Table, hw: &HwConfig, kind: TensorKind) {
    for (name, layer) in operators() {
        let mut row = vec![name.to_string()];
        for df in styles::all_styles() {
            let cell = match analyze_layer(&layer, &df, hw) {
                Ok(s) => format!("{:.1}", s.reuse_factor(kind)),
                Err(_) => "n/a".into(),
            };
            row.push(cell);
        }
        row.push(format!("{:.1}", algorithmic_max_reuse(&layer, kind)));
        t.row(&row);
    }
}
