//! Serve rate — requests/s against a resident `maestro serve` daemon,
//! cold (first-touch, every analysis runs) vs warm (answered from the
//! resident `SharedStore`). The point of DSE-as-a-service is exactly
//! this delta: the daemon pays the analytical model once per distinct
//! (layer, dataflow, hw) and every later request is a store replay.
//!
//! CI smoke mode: `SERVE_SMOKE=1 cargo bench --bench serve_rate` spins
//! an in-process daemon on an ephemeral port with a temp cache file,
//! times one cold analyze + one cold budgeted dse on the ci_smoke-sized
//! workload, then times warm repeats of both. It **asserts** the warm
//! analyze reports zero analyses and strictly beats the cold one, and
//! that the shutdown flush leaves a non-empty, loadable cache file —
//! then writes the cold/warm requests-per-second record to
//! `BENCH_serve.json` (override with `SERVE_SMOKE_OUT`), uploaded as a
//! CI build artifact. The default (non-smoke) mode runs the same
//! protocol with more warm iterations for a steadier rate estimate.
//!
//! Both modes also run a `concurrent` leg: 4 overlapping dse requests
//! against the shared-pool daemon vs the old request-per-worker
//! execution model, gated on aggregate designs/s being no worse.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

use maestro::cache::SharedStore;
use maestro::engine::analysis::Objective;
use maestro::service::api::{AnalyzeRequest, DseRequest, Request, Response};
use maestro::service::daemon::{Daemon, ServeConfig};
use maestro::service::exec;
use maestro::util::json::Json;

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to daemon");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Client { stream, reader }
    }

    fn request(&mut self, request: &Request) -> Response {
        let mut line = request.encode().dump();
        line.push('\n');
        self.stream.write_all(line.as_bytes()).expect("write frame");
        self.stream.flush().expect("flush frame");
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).expect("read reply");
        assert!(n > 0, "daemon closed the connection");
        let v = Json::parse(reply.trim()).expect("reply must be JSON");
        Response::decode(&v).unwrap_or_else(|e| panic!("undecodable reply {e:?}: {}", v.dump()))
    }
}

fn analyze_request(id: u64) -> Request {
    Request::Analyze(AnalyzeRequest {
        id: Some(id),
        model: "vgg16".into(),
        dataflow: "adaptive".into(),
        pes: 256,
        bw: 16,
        objective: Objective::Runtime,
        tile_resolution: 6,
        per_layer: false,
    })
}

fn dse_request(id: u64) -> Request {
    // ci_smoke-sized: first VGG16 layer, tiny resolution, exhaustive so
    // the warm repeat touches the identical design set.
    Request::Dse(DseRequest {
        id: Some(id),
        family: "kc-p".into(),
        model: "vgg16".into(),
        layer: String::new(),
        network: false,
        resolution: 4,
        bw_resolution: 4,
        mapspace: false,
        tile_resolution: 6,
        strategy: "exhaustive".into(),
        seed: 1,
        budget: 0,
        budget_seconds: 0.0,
        threads: 1,
        keep_points: false,
        stream: false,
    })
}

/// The concurrent leg's request: bigger than the smoke dse so the
/// aggregate rate measures sweep work rather than per-request framing.
fn concurrent_dse_request(id: u64) -> DseRequest {
    DseRequest {
        id: Some(id),
        family: "kc-p".into(),
        model: "vgg16".into(),
        layer: String::new(),
        network: false,
        resolution: 8,
        bw_resolution: 8,
        mapspace: false,
        tile_resolution: 6,
        strategy: "exhaustive".into(),
        seed: 1,
        budget: 0,
        budget_seconds: 0.0,
        threads: 1,
        keep_points: false,
        stream: false,
    }
}

fn expect_analyze(r: Response) -> maestro::service::api::AnalyzeReply {
    match r {
        Response::Analyze(a) => a,
        other => panic!("expected analyze reply, got {other:?}"),
    }
}

fn expect_dse(r: Response) -> maestro::service::api::DseReply {
    match r {
        Response::Dse(d) => d,
        other => panic!("expected dse reply, got {other:?}"),
    }
}

fn main() {
    let smoke = std::env::var("SERVE_SMOKE")
        .map(|v| matches!(v.as_str(), "1" | "true" | "TRUE"))
        .unwrap_or(false);
    let warm_iters: u64 = if smoke { 10 } else { 100 };

    // The whole bench runs with span telemetry on — sampled, the
    // documented production mode, so the per-candidate profile.finalize
    // span stays off the critical path. The concurrent gate below
    // therefore measures the *instrumented* daemon; smoke mode exports
    // the validated trace next to the BENCH record.
    maestro::obs::trace::clear();
    maestro::obs::trace::enable(8);

    let cache =
        std::env::temp_dir().join(format!("maestro_serve_bench_{}.mcache", std::process::id()));
    let _ = std::fs::remove_file(&cache);
    let daemon = Daemon::spawn(ServeConfig {
        addr: "127.0.0.1:0".into(),
        cache_file: Some(cache.display().to_string()),
        flush_every: 0.0,
        workers: 2,
        ..ServeConfig::default()
    })
    .expect("spawn daemon");
    let mut client = Client::connect(daemon.addr());
    let mut next_id = 0u64;
    let mut id = || {
        next_id += 1;
        next_id
    };

    // Cold leg: first touch pays the analytical model.
    let t0 = Instant::now();
    let cold_analyze = expect_analyze(client.request(&analyze_request(id())));
    let cold_analyze_s = t0.elapsed().as_secs_f64();
    assert!(cold_analyze.stats.analyses > 0, "cold analyze must run analyses");
    let t0 = Instant::now();
    let cold_dse = expect_dse(client.request(&dse_request(id())));
    let cold_dse_s = t0.elapsed().as_secs_f64();
    assert!(cold_dse.search.evaluated > 0, "cold dse must evaluate designs");
    println!(
        "cold: analyze {:.4}s ({} analyses), dse {:.4}s ({} designs)",
        cold_analyze_s, cold_analyze.stats.analyses, cold_dse_s, cold_dse.search.evaluated
    );

    // Warm leg: identical requests answered from the resident store.
    let t0 = Instant::now();
    let mut warm_hits_total = 0u64;
    for _ in 0..warm_iters {
        let warm = expect_analyze(client.request(&analyze_request(id())));
        assert_eq!(warm.stats.analyses, 0, "warm analyze must not re-analyze: {:?}", warm.stats);
        assert!(warm.stats.warm_hits > 0, "warm analyze must hit the store: {:?}", warm.stats);
        assert_eq!(warm.runtime_cycles, cold_analyze.runtime_cycles, "replay must be bit-identical");
        warm_hits_total += warm.stats.warm_hits;
    }
    let warm_analyze_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let warm_dse = expect_dse(client.request(&dse_request(id())));
    let warm_dse_s = t0.elapsed().as_secs_f64();
    assert_eq!(warm_dse.stats.analyses, 0, "warm dse must replay: {:?}", warm_dse.stats);
    assert_eq!(warm_dse.frontier, cold_dse.frontier, "warm frontier must be bit-identical");

    let cold_rps = 1.0 / cold_analyze_s.max(1e-9);
    let warm_rps = warm_iters as f64 / warm_analyze_s.max(1e-9);
    let per_warm = warm_analyze_s / warm_iters as f64;
    println!(
        "warm: analyze {warm_iters} x {:.5}s avg ({} store hits), dse {:.4}s",
        per_warm, warm_hits_total, warm_dse_s
    );
    println!(
        "requests/s: cold {:.1} -> warm {:.1} (x{:.1} speedup)",
        cold_rps,
        warm_rps,
        warm_rps / cold_rps.max(1e-9)
    );
    assert!(
        per_warm < cold_analyze_s,
        "warm ({per_warm:.5}s) must be strictly faster than cold ({cold_analyze_s:.5}s)"
    );

    // Shutdown flushes the store; the file must replay standalone.
    match client.request(&Request::Shutdown) {
        Response::Done(d) => assert_eq!(d.what, "shutdown"),
        other => panic!("expected done reply, got {other:?}"),
    }
    daemon.join().expect("clean daemon exit");
    let store = SharedStore::new();
    let report = store.load(&cache);
    assert!(report.warning.is_none(), "{:?}", report.warning);
    assert!(report.loaded > 0, "shutdown flush must persist records");
    println!("shutdown flush: {} record(s) on disk", report.loaded);

    // ----------------------------------------------------------------
    // Concurrent leg: 4 overlapping dse requests, shared-pool vs the
    // old request-per-worker execution model. Both sides start from a
    // fresh store and run the identical request mix, so the aggregate
    // designs/s compares scheduling, not warmth.
    // ----------------------------------------------------------------
    use std::sync::Arc;

    let conc_reqs: Vec<maestro::service::api::DseRequest> =
        (0..4).map(|i| concurrent_dse_request(200 + i)).collect();

    // Baseline first (page-cache order favors neither side strongly,
    // and what tilt exists goes to the leg measured second): 2 worker
    // threads, each running whole requests serially with threads=1 —
    // the pre-shared-pool daemon's execution model, per-request case
    // tables included.
    let base_store = Arc::new(SharedStore::new());
    let t0 = Instant::now();
    let base_designs: u64 = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|w| {
                let reqs = &conc_reqs;
                let store = &base_store;
                scope.spawn(move || {
                    let mut designs = 0u64;
                    for req in reqs.iter().skip(w).step_by(2) {
                        let prep = exec::prepare_dse(req).expect("prepare baseline dse");
                        let out = exec::run_prepared_dse(store, &prep, req, true, None)
                            .expect("run baseline dse");
                        designs += out.stats.designs_evaluated;
                    }
                    designs
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("baseline worker")).sum()
    });
    let base_s = t0.elapsed().as_secs_f64();

    // Shared pool: a fresh daemon with 2 pool workers, 4 clients
    // submitting at once; the scheduler interleaves all four sweeps
    // into shared waves over one store and one table cache.
    let conc_daemon =
        Daemon::spawn(ServeConfig { addr: "127.0.0.1:0".into(), workers: 2, ..ServeConfig::default() })
            .expect("spawn concurrent-leg daemon");
    let conc_addr = conc_daemon.addr();
    let t0 = Instant::now();
    let replies: Vec<maestro::service::api::DseReply> = std::thread::scope(|scope| {
        let handles: Vec<_> = conc_reqs
            .iter()
            .map(|req| {
                let req = Request::Dse(req.clone());
                scope.spawn(move || {
                    let mut c = Client::connect(conc_addr);
                    expect_dse(c.request(&req))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("concurrent client")).collect()
    });
    let shared_s = t0.elapsed().as_secs_f64();
    let shared_designs: u64 = replies.iter().map(|r| r.stats.designs_evaluated).sum();
    for r in &replies {
        assert_eq!(r.frontier, replies[0].frontier, "identical requests must agree bit-for-bit");
        assert!(r.search.evaluated > 0, "every concurrent dse must evaluate designs");
    }
    let mut closer = Client::connect(conc_addr);
    match closer.request(&Request::Shutdown) {
        Response::Done(d) => assert_eq!(d.what, "shutdown"),
        other => panic!("expected done reply, got {other:?}"),
    }
    conc_daemon.join().expect("clean concurrent-leg daemon exit");

    let base_dps = base_designs as f64 / base_s.max(1e-9);
    let shared_dps = shared_designs as f64 / shared_s.max(1e-9);
    println!(
        "concurrent: shared-pool {shared_designs} designs in {shared_s:.4}s ({shared_dps:.0}/s) \
         vs request-per-worker {base_designs} in {base_s:.4}s ({base_dps:.0}/s)"
    );
    // Gate: shared-pool aggregate throughput must be no worse. The 0.9
    // factor absorbs transport + scheduler overhead measurement noise
    // on the smoke-sized workload; a real scheduling regression shows
    // up far below it.
    assert!(
        shared_dps >= 0.9 * base_dps,
        "shared-pool aggregate throughput regressed: {shared_dps:.0} designs/s vs \
         request-per-worker {base_dps:.0} designs/s"
    );

    if smoke {
        let json = format!(
            "{{\n  \"bench\": \"serve_rate\",\n  \"workload\": \"vgg16 adaptive analyze + kc-p dse \
             (resolution 4, exhaustive)\",\n  \"cold\": {{\"analyze_seconds\": {cold_analyze_s:.6}, \
             \"dse_seconds\": {cold_dse_s:.6}, \"analyses\": {}, \"requests_per_s\": {cold_rps:.2}}},\n  \
             \"warm\": {{\"iterations\": {warm_iters}, \"analyze_seconds_total\": {warm_analyze_s:.6}, \
             \"analyze_seconds_avg\": {per_warm:.6}, \"dse_seconds\": {warm_dse_s:.6}, \
             \"store_hits\": {warm_hits_total}, \"requests_per_s\": {warm_rps:.2}}},\n  \
             \"speedup\": {:.2},\n  \"flushed_records\": {},\n  \
             \"concurrent\": {{\"requests\": 4, \
             \"shared_pool\": {{\"designs\": {shared_designs}, \"seconds\": {shared_s:.6}, \
             \"designs_per_s\": {shared_dps:.2}}}, \
             \"request_per_worker\": {{\"designs\": {base_designs}, \"seconds\": {base_s:.6}, \
             \"designs_per_s\": {base_dps:.2}}}}}\n}}\n",
            cold_analyze.stats.analyses,
            warm_rps / cold_rps.max(1e-9),
            report.loaded,
        );
        let path = std::env::var("SERVE_SMOKE_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
        std::fs::write(&path, json).expect("write bench smoke json");
        println!("wrote {path}");

        // Every daemon and worker thread is joined, so no span is open:
        // the export must pass the structural validator before it is
        // written (write_file refuses malformed traces).
        let trace_path =
            std::env::var("SERVE_TRACE_OUT").unwrap_or_else(|_| "TRACE_serve.json".into());
        let summary = maestro::obs::trace::write_file(&trace_path).expect("bench trace validates");
        assert!(summary.events > 0, "an instrumented bench run must record spans");
        println!(
            "wrote {trace_path} ({} events, {} threads, max depth {})",
            summary.events, summary.threads, summary.max_depth
        );
    }
    let _ = std::fs::remove_file(&cache);
}
