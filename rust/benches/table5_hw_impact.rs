//! Table 5 — the impact of multicast capability, NoC bandwidth and
//! spatial-reduction support on a KC-P design running VGG16-CONV2.
//!
//! Paper rows (56 PEs): reference (BW 40, multicast+reduction),
//! small bandwidth (BW 24: throughput drops, energy unchanged),
//! no multicast (+~44% energy), no spatial reduction (+~48% energy).

use maestro::dse::space::kc_p_ct;
use maestro::engine::analysis::analyze_layer;
use maestro::hw::config::{HwConfig, ReductionSupport};
use maestro::model::zoo::vgg16;
use maestro::util::benchkit::section;
use maestro::util::table::Table;

fn main() {
    section("Table 5: hardware reuse-support impact, KC-P on VGG16-CONV2");
    let layer = vgg16::conv2();
    // 56 PEs like the paper's design point; KC-P needs its cluster to
    // fit, so use the ct=8 variant (56 = 7 clusters x 8 PEs).
    let df = kc_p_ct(8);
    let base = HwConfig {
        num_pes: 56,
        noc_bandwidth: 40,
        noc_latency: 2,
        ..HwConfig::fig10_default()
    };

    let configs: Vec<(&str, HwConfig)> = vec![
        ("Reference", base.clone()),
        ("Small bandwidth", HwConfig { noc_bandwidth: 24, ..base.clone() }),
        ("No multicast", HwConfig { multicast: false, ..base.clone() }),
        ("No Sp. reduction", HwConfig { reduction: ReductionSupport::None, ..base.clone() }),
    ];

    let mut t = Table::new(&[
        "design point", "PEs", "NoC BW", "multicast", "reduction",
        "throughput (MAC/cyc)", "energy (uJ)", "energy vs ref",
    ]);
    let mut ref_energy = None;
    let mut ref_thrpt = None;
    for (name, hw) in &configs {
        let s = analyze_layer(&layer, &df, hw).unwrap();
        let thrpt = s.throughput();
        let energy = s.energy.total();
        if ref_energy.is_none() {
            ref_energy = Some(energy);
            ref_thrpt = Some(thrpt);
        }
        t.row(&[
            name.to_string(),
            hw.num_pes.to_string(),
            hw.noc_bandwidth.to_string(),
            (if hw.multicast { "Yes" } else { "No" }).into(),
            (if hw.reduction == ReductionSupport::None { "No" } else { "Yes" }).into(),
            format!("{thrpt:.2}"),
            format!("{:.2}", energy / 1e6),
            format!("{:+.1}%", (energy / ref_energy.unwrap() - 1.0) * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!(
        "paper shape: small BW cuts throughput (48.6 -> 34.5) at ~equal energy; removing multicast or spatial reduction costs ~44-48% energy."
    );
    let _ = ref_thrpt;
}
