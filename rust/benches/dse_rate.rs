//! DSE rate — the paper's headline systems number: "480M designs
//! searched, 2.5M valid, at an average effective rate of 0.17M designs
//! per second" (§1, §5.2, Fig 13c).
//!
//! Measures: (a) the pruned scalar sweep rate, (b) the coordinator with
//! multiple workers, and (c) the PJRT batched evaluator (the AOT Pallas
//! kernel) vs the scalar backend on identical jobs.

use maestro::coordinator::{run_jobs, Backend, DseJob};
use maestro::dse::engine::sweep;
use maestro::dse::space::{geometric_range, kc_p_variants, DesignSpace};
use maestro::model::zoo::vgg16;
use maestro::runtime::{BatchEvaluator, DesignIn};
use maestro::util::benchkit::{bench_throughput, fmt_rate, section};

fn space(resolution: usize) -> DesignSpace {
    DesignSpace::fig13("kc-p", resolution)
}

fn main() {
    let layer = vgg16::conv2();

    section("DSE rate (a): pruned scalar sweep (single thread)");
    for resolution in [16usize, 32, 48] {
        let sp = space(resolution);
        let (points, stats) = sweep(&[&layer], &sp, 2).unwrap();
        println!(
            "resolution {resolution:>3}: {:>8} designs ({} evaluated, {} valid) in {:.2}s -> effective rate {}/s (paper avg 0.17M/s)",
            stats.total_designs,
            stats.evaluated,
            stats.valid,
            stats.seconds,
            fmt_rate(stats.rate()),
        );
        assert!(!points.is_empty());
    }

    section("DSE rate (b): coordinator scaling (scalar backend)");
    let designs: Vec<DesignIn> = geometric_range(1, 256, 64)
        .into_iter()
        .map(|bw| DesignIn { bandwidth: bw as f64, latency: 2.0, l1: 0.0, l2: 0.0 })
        .collect();
    let mk_jobs = || -> Vec<DseJob> {
        let mut jobs = Vec::new();
        let mut id = 0;
        for variant in kc_p_variants() {
            for pes in geometric_range(8, 2048, 24) {
                id += 1;
                jobs.push(DseJob {
                    id,
                    layers: vec![layer.clone()],
                    variant: variant.clone(),
                    pes,
                    designs: designs.clone(),
                    noc_hops: 2,
                    area_budget: 16.0,
                    power_budget: 450.0,
                });
            }
        }
        jobs
    };
    for workers in [1usize, 2, 4, 8] {
        let jobs = mk_jobs();
        let n_designs: u64 = jobs.iter().map(|j| j.designs.len() as u64).sum();
        let t0 = std::time::Instant::now();
        let (results, _metrics) = run_jobs(jobs, Backend::Scalar, workers).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "workers {workers}: {} jobs, {} designs in {secs:.2}s -> {}/s",
            results.len(),
            n_designs,
            fmt_rate(n_designs as f64 / secs)
        );
    }

    section("DSE rate (c): PJRT batched evaluator vs scalar (same jobs, full batches)");
    // Dense per-job sweep that fills the artifact's 512-design batches:
    // 64 bandwidths x 4 latencies x 2 L1 placements.
    let dense_designs: Vec<DesignIn> = {
        let mut v = Vec::new();
        for bw in geometric_range(1, 256, 64) {
            for lat in [1u64, 2, 4, 8] {
                for l1_scale in [1u64, 4] {
                    v.push(DesignIn {
                        bandwidth: bw as f64,
                        latency: lat as f64,
                        l1: (512 * l1_scale) as f64,
                        l2: 262_144.0,
                    });
                }
            }
        }
        v
    };
    let mk_dense_jobs = || -> Vec<DseJob> {
        let mut jobs = Vec::new();
        let mut id = 0;
        for variant in kc_p_variants() {
            for pes in geometric_range(8, 2048, 24) {
                id += 1;
                jobs.push(DseJob {
                    id,
                    layers: vec![layer.clone()],
                    variant: variant.clone(),
                    pes,
                    designs: dense_designs.clone(),
                    noc_hops: 2,
                    area_budget: 16.0,
                    power_budget: 450.0,
                });
            }
        }
        jobs
    };
    let artifact = BatchEvaluator::default_path();
    if artifact.exists() {
        for (name, backend) in [
            ("scalar", Backend::Scalar),
            ("pjrt  ", Backend::Pjrt(artifact.clone())),
        ] {
            let jobs = mk_dense_jobs();
            let n_designs: u64 = jobs.iter().map(|j| j.designs.len() as u64).sum();
            let t0 = std::time::Instant::now();
            let _ = run_jobs(jobs, backend, 4).unwrap();
            let secs = t0.elapsed().as_secs_f64();
            println!("{name}: {} designs in {secs:.2}s -> {}/s", n_designs, fmt_rate(n_designs as f64 / secs));
        }
    } else {
        println!("artifact missing (run `make artifacts`); skipping PJRT comparison");
    }

    section("DSE rate (d): raw scalar evaluation throughput");
    let table = maestro::dse::engine::build_case_table(&[&layer], &kc_p_variants()[3], 256).unwrap();
    bench_throughput("eval_runtime x10k designs", 10_000, 2, 10, || {
        let mut acc = 0.0;
        for bw in 1..=100u64 {
            for lat in 0..100u64 {
                acc += maestro::dse::engine::eval_runtime(&table, bw, lat % 5);
            }
        }
        acc
    });
}
