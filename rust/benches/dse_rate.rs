//! DSE rate — the paper's headline systems number: "480M designs
//! searched, 2.5M valid, at an average effective rate of 0.17M designs
//! per second" (§1, §5.2, Fig 13c).
//!
//! Measures: (a) the sharded sweep engine across thread counts, (b) the
//! coordinator with multiple workers, and (c) the PJRT batched
//! evaluator (the AOT Pallas kernel) vs the scalar backend on identical
//! jobs.
//!
//! CI smoke mode: `DSE_SMOKE=1 cargo bench --bench dse_rate` runs the
//! sharded sweep on the tiny `DesignSpace::ci_smoke` space in seconds,
//! plus a cache-file warm-start round trip (which *does* assert: the
//! cache file must load warning-free and the warm sweep must report
//! disk hits) and a two-phase table-reuse leg on a 9-point bandwidth
//! axis (which asserts the profiled guided sweep is at least as fast
//! as the rebuild-every-visit reference with a bit-identical
//! frontier), and writes the designs/s + thread-scaling + warm-start +
//! `profile_vs_monolithic` numbers to `BENCH_dse_rate.json` (override
//! with `DSE_SMOKE_OUT`) — uploaded as a CI build artifact.

use maestro::coordinator::{run_jobs, Backend, DseJob};
use maestro::dse::engine::{sweep, SweepConfig, SweepStats};
use maestro::dse::space::{geometric_range, kc_p_variants, DesignSpace};
use maestro::dse::strategy::SearchStrategy;
use maestro::model::network::Network;
use maestro::model::zoo::vgg16;
use maestro::runtime::{BatchEvaluator, DesignIn};
use maestro::util::benchkit::{bench_throughput, fmt_rate, section};

const SWEEP_THREADS: [usize; 4] = [1, 2, 4, 8];

fn sweep_scaling(net: &Network, space: &DesignSpace) -> Vec<(usize, SweepStats)> {
    let mut runs = Vec::new();
    for threads in SWEEP_THREADS {
        let cfg = SweepConfig { threads, ..SweepConfig::default() };
        let outcome = sweep(net, space, 2, &cfg).unwrap();
        println!("threads {threads}: {}", outcome.stats.summary());
        runs.push((threads, outcome.stats));
    }
    runs
}

/// Hand-rolled JSON record (no serde in the image): one object per
/// thread count, seeding the `BENCH_*.json` trajectory. The workload is
/// part of the record — PR 2 switched the smoke from a single layer to
/// the whole VGG16 conv stack, so designs/s is not comparable across
/// records with different workloads.
fn scaling_json(
    resolution: &str,
    net: &Network,
    runs: &[(usize, SweepStats)],
    warm: (&SweepStats, &SweepStats),
    guided: (&SweepStats, &SweepStats, bool),
    table_reuse: (&SweepStats, &SweepStats),
    mapspace: &str,
) -> String {
    let mut s = String::from("{\n");
    s += "  \"bench\": \"dse_rate\",\n";
    s += &format!("  \"space\": \"{resolution}\",\n");
    s += &format!("  \"workload\": \"{}\",\n", net.name);
    s += &format!("  \"workload_layers\": {},\n", net.layers.len());
    s += &format!("  \"workload_unique_shapes\": {},\n", net.unique_shapes().len());
    s += "  \"runs\": [\n";
    for (i, (threads, st)) in runs.iter().enumerate() {
        s += &format!(
            "    {{\"threads\": {threads}, \"total_designs\": {}, \"evaluated\": {}, \"valid\": {}, \
             \"pruned\": {}, \"unmappable\": {}, \"cache_hits\": {}, \"cache_disk_hits\": {}, \
             \"cache_misses\": {}, \"seconds\": {:.6}, \"designs_per_s\": {:.1}}}{}\n",
            st.total_designs,
            st.evaluated,
            st.valid,
            st.pruned,
            st.unmappable,
            st.cache_hits,
            st.cache_disk_hits,
            st.cache_misses,
            st.seconds,
            st.rate(),
            if i + 1 < runs.len() { "," } else { "" },
        );
    }
    s += "  ],\n";
    let (cold, rewarm) = warm;
    s += &format!(
        "  \"warm_start\": {{\"cold_seconds\": {:.6}, \"warm_seconds\": {:.6}, \"cache_disk_hits\": {}, \
         \"cache_misses_warm\": {}}},\n",
        cold.seconds, rewarm.seconds, rewarm.cache_disk_hits, rewarm.cache_misses,
    );
    // ISSUE 4 acceptance record: guided must reach the exhaustive
    // frontier at a fraction of the evaluations (ratio < 0.5).
    let (exhaustive, guided_stats, frontier_reached) = guided;
    s += &format!(
        "  \"guided_vs_exhaustive\": {{\"exhaustive_evaluated\": {}, \"guided_evaluated\": {}, \
         \"eval_ratio\": {:.4}, \"guided_waves\": {}, \"frontier_reached\": {}}},\n",
        exhaustive.evaluated,
        guided_stats.evaluated,
        guided_stats.evaluated as f64 / exhaustive.evaluated.max(1) as f64,
        guided_stats.waves,
        frontier_reached,
    );
    // ISSUE 8 acceptance record: the guided sweep with sweep-lifetime
    // per-pair case tables vs the rebuild-every-visit reference on a
    // 9-point bandwidth axis (CI asserts profiled >= monolithic rate
    // and a bit-identical frontier before this record is written).
    let (mono, prof) = table_reuse;
    s += &format!(
        "  \"profile_vs_monolithic\": {{\"monolithic_designs_per_s\": {:.1}, \
         \"profiled_designs_per_s\": {:.1}, \"speedup\": {:.4}, \"profile_hits\": {}, \
         \"guided_waves\": {}}},\n",
        mono.rate(),
        prof.rate(),
        prof.rate() / mono.rate().max(1e-9),
        prof.profile_hits,
        prof.waves,
    );
    // ISSUE 5 acceptance record: mapspace size + layer-wise mapper vs
    // the best fixed Table 3 style on the smoke network.
    s += &format!("  \"mapspace\": {mapspace}\n");
    s += "}\n";
    s
}

/// CI smoke: tiny space, scaling record + a cache-file warm-start round
/// trip written to disk, done. The workload is the whole VGG16 conv
/// stack so the shard Analyzers' mem/disk hit and miss counters land in
/// the JSON trajectory.
fn run_smoke(net: &Network) {
    use maestro::cache::SharedStore;
    use std::sync::Arc;

    section("DSE bench smoke (CI): sharded network sweep on DesignSpace::ci_smoke");
    // Smoke runs instrumented: sampled span telemetry (the documented
    // production mode) across every leg, exported as a validated Chrome
    // trace next to the BENCH record. Both sides of the table-reuse
    // rate gate below run equally traced.
    maestro::obs::trace::clear();
    maestro::obs::trace::enable(8);
    let space = DesignSpace::ci_smoke("kc-p");
    let runs = sweep_scaling(net, &space);

    // Warm-start leg: cold shared-store sweep -> flush -> fresh store
    // load -> warm sweep (all analyses replay from disk).
    let cache_path =
        std::env::temp_dir().join(format!("maestro_dse_smoke_{}.mcache", std::process::id()));
    let _ = std::fs::remove_file(&cache_path);
    let store = Arc::new(SharedStore::new());
    let cold_cfg = SweepConfig { threads: 1, cache: Some(Arc::clone(&store)), ..SweepConfig::default() };
    let cold = sweep(net, &space, 2, &cold_cfg).unwrap();
    store.flush(&cache_path).expect("flush smoke cache");
    let warm_store = Arc::new(SharedStore::new());
    let loaded = warm_store.load(&cache_path);
    assert!(loaded.warning.is_none(), "{:?}", loaded.warning);
    let warm_cfg = SweepConfig { threads: 1, cache: Some(warm_store), ..SweepConfig::default() };
    let warm = sweep(net, &space, 2, &warm_cfg).unwrap();
    let _ = std::fs::remove_file(&cache_path);
    println!("cache-file cold: {}", cold.stats.summary());
    println!("cache-file warm: {}", warm.stats.summary());
    assert!(warm.stats.cache_disk_hits > 0, "warm sweep must report disk hits");

    // Guided-vs-exhaustive leg (ISSUE 4 acceptance, also a CI test):
    // the guided strategy must reach the exhaustive frontier's
    // objective values while evaluating < 50% of what the exhaustive
    // sweep evaluates; the ratio lands in the JSON trajectory.
    let exhaustive = sweep(net, &space, 2, &SweepConfig::serial()).unwrap();
    let guided = sweep(
        net,
        &space,
        2,
        &SweepConfig { strategy: SearchStrategy::ParetoGuided, ..SweepConfig::serial() },
    )
    .unwrap();
    let values = maestro::dse::pareto::objective_values;
    let frontier_reached = values(&guided.frontier) == values(&exhaustive.frontier);
    let ratio = guided.stats.evaluated as f64 / exhaustive.stats.evaluated.max(1) as f64;
    println!("exhaustive: {}", exhaustive.stats.summary());
    println!("guided:     {}", guided.stats.summary());
    println!("guided-vs-exhaustive: eval ratio {ratio:.3}, frontier reached: {frontier_reached}");
    assert!(frontier_reached, "guided must reach the exhaustive frontier on the smoke space");
    assert!(ratio < 0.5, "guided must evaluate under half the designs (got {ratio:.3})");

    // Two-phase leg (ISSUE 8 acceptance, also a CI gate): the guided
    // sweep with sweep-lifetime per-pair case tables (the default) vs
    // the rebuild-every-visit reference (`reuse_tables: false`), on the
    // smoke space deepened to the canonical 9-point bandwidth axis —
    // the axis the reuse makes near-free. Frontiers and counts must be
    // bit-identical, and the profiled sweep must not be slower. Each
    // variant runs twice and keeps its faster run to damp CI timer
    // noise; the gate compares real work, not scheduler luck.
    let deep = DesignSpace::fig13_axes("kc-p", 5, 9);
    let reuse_cfg = SweepConfig { strategy: SearchStrategy::ParetoGuided, ..SweepConfig::serial() };
    let rebuild_cfg = SweepConfig { reuse_tables: false, ..reuse_cfg.clone() };
    let faster_of = |cfg: &SweepConfig| {
        let a = sweep(net, &deep, 2, cfg).unwrap();
        let b = sweep(net, &deep, 2, cfg).unwrap();
        if a.stats.seconds <= b.stats.seconds { a } else { b }
    };
    let profiled = faster_of(&reuse_cfg);
    let monolithic = faster_of(&rebuild_cfg);
    println!("table-reuse on : {}", profiled.stats.summary());
    println!("table-reuse off: {}", monolithic.stats.summary());
    assert_eq!(
        profiled.frontier, monolithic.frontier,
        "table reuse must leave the frontier bit-identical"
    );
    assert_eq!(profiled.stats.evaluated, monolithic.stats.evaluated);
    assert_eq!(profiled.stats.valid, monolithic.stats.valid);
    assert!(
        profiled.stats.rate() >= monolithic.stats.rate(),
        "profiled sweep must be at least as fast as the rebuild-every-visit reference: \
         {:.1} designs/s < {:.1} designs/s",
        profiled.stats.rate(),
        monolithic.stats.rate(),
    );

    // Mapspace leg (ISSUE 5 acceptance record): the layer-wise mapper
    // over the generated tiling space vs the best single fixed Table 3
    // style on the same network. The mapper's candidate set contains
    // every fixed style that maps (defaults always enumerated), so it
    // can never lose; the improvement lands in the JSON trajectory.
    let hw = maestro::hw::config::HwConfig::fig10_default();
    let mut mapper = maestro::mapspace::Mapper::new();
    let mapped = mapper
        .map_network(net, &hw, &maestro::mapspace::MapperConfig::default())
        .expect("mapper must map the smoke network");
    let mut best_fixed = f64::INFINITY;
    let mut best_fixed_name = String::from("none");
    for df in maestro::ir::styles::all_styles() {
        if let Ok(s) = maestro::engine::analysis::analyze_network(net, &df, &hw, true) {
            if s.per_layer.len() == net.layers.len() && s.runtime < best_fixed {
                best_fixed = s.runtime;
                best_fixed_name = df.name.clone();
            }
        }
    }
    assert!(
        best_fixed.is_finite(),
        "no fixed Table 3 style maps every smoke-network layer; the mapspace record would be \
         invalid JSON (inf) — fix the smoke workload or the comparison"
    );
    let improvement = best_fixed / mapped.network.runtime.max(1e-12);
    println!("mapper: {}", mapped.stats.summary());
    println!(
        "mapper-vs-fixed: runtime {} vs best fixed '{best_fixed_name}' {} -> x{improvement:.4}",
        mapped.network.runtime, best_fixed
    );
    assert!(
        mapped.network.runtime <= best_fixed * (1.0 + 1e-9),
        "the mapper's space contains the fixed styles; it cannot lose"
    );
    let mapspace_json = format!(
        "{{\"shapes\": {}, \"combos\": {}, \"candidates\": {}, \"evaluated\": {}, \
         \"mapper_runtime\": {:.3}, \"best_fixed\": \"{best_fixed_name}\", \
         \"best_fixed_runtime\": {:.3}, \"runtime_improvement\": {improvement:.4}}}",
        mapped.stats.shapes,
        mapped.stats.combos,
        mapped.stats.candidates,
        mapped.stats.evaluated,
        mapped.network.runtime,
        best_fixed,
    );

    let json = scaling_json(
        "ci_smoke(kc-p)",
        net,
        &runs,
        (&cold.stats, &warm.stats),
        (&exhaustive.stats, &guided.stats, frontier_reached),
        (&monolithic.stats, &profiled.stats),
        &mapspace_json,
    );
    let path = std::env::var("DSE_SMOKE_OUT").unwrap_or_else(|_| "BENCH_dse_rate.json".into());
    std::fs::write(&path, json).expect("write bench smoke json");
    println!("wrote {path}");

    // All sweep/mapper worker scopes have joined, so no span is open:
    // the export must pass the structural validator before it is
    // written (write_file refuses malformed traces).
    let trace_path =
        std::env::var("DSE_TRACE_OUT").unwrap_or_else(|_| "TRACE_dse_rate.json".into());
    let summary = maestro::obs::trace::write_file(&trace_path).expect("bench trace validates");
    assert!(summary.events > 0, "an instrumented smoke run must record spans");
    println!(
        "wrote {trace_path} ({} events, {} threads, max depth {})",
        summary.events, summary.threads, summary.max_depth
    );
}

fn main() {
    let layer = vgg16::conv2();
    let single = Network::single(layer.clone());
    let smoke = std::env::var("DSE_SMOKE")
        .map(|v| matches!(v.as_str(), "1" | "true" | "TRUE"))
        .unwrap_or(false);
    if smoke {
        run_smoke(&vgg16::conv_only());
        return;
    }

    section("DSE rate (a): sharded sweep, single thread across resolutions");
    for resolution in [16usize, 32, 48] {
        let sp = DesignSpace::fig13("kc-p", resolution);
        let out = sweep(&single, &sp, 2, &SweepConfig::serial()).unwrap();
        println!(
            "resolution {resolution:>3}: {} (paper avg 0.17M/s); frontier {} points",
            out.stats.summary(),
            out.frontier.len(),
        );
        assert!(!out.frontier.is_empty());
    }

    section("DSE rate (a2): sharded sweep thread scaling (resolution 32)");
    let sp = DesignSpace::fig13("kc-p", 32);
    let runs = sweep_scaling(&single, &sp);
    let base = runs[0].1.seconds;
    for (threads, st) in &runs[1..] {
        println!("  speedup x{:.2} at {threads} threads", base / st.seconds.max(1e-9));
    }

    section("DSE rate (a3): whole-network sweep (VGG16 conv stack, shape-deduplicated)");
    let net = vgg16::conv_only();
    let sp = DesignSpace::fig13("kc-p", 12);
    for cfg in [SweepConfig::serial(), SweepConfig::default()] {
        let out = sweep(&net, &sp, 2, &cfg).unwrap();
        println!(
            "threads {}: {} ({} layers, {} unique shapes)",
            if cfg.threads == 1 { "1".to_string() } else { "all".to_string() },
            out.stats.summary(),
            net.layers.len(),
            net.unique_shapes().len(),
        );
    }

    section("DSE rate (a4): search strategies vs exhaustive (resolution 16)");
    let sp = DesignSpace::fig13("kc-p", 16);
    let exhaustive = sweep(&single, &sp, 2, &SweepConfig::default()).unwrap();
    println!("exhaustive: {}", exhaustive.stats.summary());
    for (label, cfg) in [
        (
            "random 25%",
            SweepConfig {
                strategy: SearchStrategy::RandomSample { seed: 7 },
                budget: maestro::dse::strategy::SearchBudget {
                    max_designs: sp.size() / 4,
                    ..Default::default()
                },
                ..SweepConfig::default()
            },
        ),
        ("guided    ", SweepConfig { strategy: SearchStrategy::ParetoGuided, ..SweepConfig::default() }),
    ] {
        let out = sweep(&single, &sp, 2, &cfg).unwrap();
        println!(
            "{label}: {} (frontier {} vs exhaustive {} points)",
            out.stats.summary(),
            out.frontier.len(),
            exhaustive.frontier.len(),
        );
    }

    section("DSE rate (b): coordinator scaling (scalar backend)");
    let designs: Vec<DesignIn> = geometric_range(1, 256, 64)
        .into_iter()
        .map(|bw| DesignIn { bandwidth: bw as f64, latency: 2.0, l1: 0.0, l2: 0.0 })
        .collect();
    let mk_jobs = || -> Vec<DseJob> {
        let mut jobs = Vec::new();
        let mut id = 0;
        for variant in kc_p_variants() {
            for pes in geometric_range(8, 2048, 24) {
                id += 1;
                jobs.push(DseJob {
                    id,
                    network: Network::single(layer.clone()),
                    variant: variant.clone(),
                    pes,
                    designs: designs.clone(),
                    noc_hops: 2,
                    area_budget: 16.0,
                    power_budget: 450.0,
                });
            }
        }
        jobs
    };
    for workers in [1usize, 2, 4, 8] {
        let jobs = mk_jobs();
        let n_designs: u64 = jobs.iter().map(|j| j.designs.len() as u64).sum();
        let t0 = std::time::Instant::now();
        let (results, _metrics) = run_jobs(jobs, Backend::Scalar, workers).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        println!(
            "workers {workers}: {} jobs, {} designs in {secs:.2}s -> {}/s",
            results.len(),
            n_designs,
            fmt_rate(n_designs as f64 / secs)
        );
    }

    section("DSE rate (c): PJRT batched evaluator vs scalar (same jobs, full batches)");
    // Dense per-job sweep that fills the artifact's 512-design batches:
    // 64 bandwidths x 4 latencies x 2 L1 placements.
    let dense_designs: Vec<DesignIn> = {
        let mut v = Vec::new();
        for bw in geometric_range(1, 256, 64) {
            for lat in [1u64, 2, 4, 8] {
                for l1_scale in [1u64, 4] {
                    v.push(DesignIn {
                        bandwidth: bw as f64,
                        latency: lat as f64,
                        l1: (512 * l1_scale) as f64,
                        l2: 262_144.0,
                    });
                }
            }
        }
        v
    };
    let mk_dense_jobs = || -> Vec<DseJob> {
        let mut jobs = Vec::new();
        let mut id = 0;
        for variant in kc_p_variants() {
            for pes in geometric_range(8, 2048, 24) {
                id += 1;
                jobs.push(DseJob {
                    id,
                    network: Network::single(layer.clone()),
                    variant: variant.clone(),
                    pes,
                    designs: dense_designs.clone(),
                    noc_hops: 2,
                    area_budget: 16.0,
                    power_budget: 450.0,
                });
            }
        }
        jobs
    };
    let artifact = BatchEvaluator::default_path();
    if artifact.exists() {
        for (name, backend) in [
            ("scalar", Backend::Scalar),
            ("pjrt  ", Backend::Pjrt(artifact.clone())),
        ] {
            let jobs = mk_dense_jobs();
            let n_designs: u64 = jobs.iter().map(|j| j.designs.len() as u64).sum();
            let t0 = std::time::Instant::now();
            let _ = run_jobs(jobs, backend, 4).unwrap();
            let secs = t0.elapsed().as_secs_f64();
            println!("{name}: {} designs in {secs:.2}s -> {}/s", n_designs, fmt_rate(n_designs as f64 / secs));
        }
    } else {
        println!("artifact missing (run `make artifacts`); skipping PJRT comparison");
    }

    section("DSE rate (d): raw scalar evaluation throughput");
    let table = maestro::dse::engine::build_case_table(&[&layer], &kc_p_variants()[3], 256).unwrap();
    bench_throughput("eval_runtime x10k designs", 10_000, 2, 10, || {
        let mut acc = 0.0;
        for bw in 1..=100u64 {
            for lat in 0..100u64 {
                acc += maestro::dse::engine::eval_runtime(&table, bw, lat % 5);
            }
        }
        acc
    });
}
