//! Fig 10 — runtime and energy of the five Table 3 dataflows across the
//! five evaluation DNNs (256 PEs, 16 elements/cycle ≈ 32 GB/s NoC),
//! plus (f): per-operator-class averages and the adaptive dataflow.
//!
//! Paper's qualitative shape to reproduce: KC-P lowest runtime/energy
//! overall; YR-P most energy-efficient on VGG16; YX-P fastest on UNet;
//! adaptive ≈ 37% runtime and 10% energy reduction.

use std::collections::BTreeMap;

use maestro::engine::analysis::{adaptive_network, analyze_layer, analyze_network, Objective};
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::model::layer::OpClass;
use maestro::model::zoo;
use maestro::util::benchkit::{bench, section};
use maestro::util::table::{num, Table};

fn main() {
    let hw = HwConfig::fig10_default();
    let dataflows = styles::all_styles();

    section("Fig 10 (a-e): runtime and energy per (model, dataflow), 256 PEs / 16 el-per-cyc NoC");
    let mut t = Table::new(&["model", "dataflow", "runtime (Mcyc)", "energy (uJ)", "layers"]);
    let mut results: BTreeMap<(String, String), (f64, f64)> = BTreeMap::new();

    for model in zoo::FIG10_MODELS {
        let net = zoo::by_name(model).unwrap();
        for df in &dataflows {
            let Ok(s) = analyze_network(&net, df, &hw, true) else { continue };
            t.row(&[
                model.to_string(),
                df.name.clone(),
                format!("{:.1}", s.runtime / 1e6),
                num(s.energy.total() / 1e6),
                s.per_layer.len().to_string(),
            ]);
            results.insert((model.to_string(), df.name.clone()), (s.runtime, s.energy.total()));
        }
    }
    print!("{}", t.render());

    // Paper shape checks (reported, not asserted — benches are reports).
    if let (Some(&(kc_rt, kc_en)), Some(&(yr_rt, yr_en))) = (
        results.get(&("vgg16".into(), "KC-P".into())),
        results.get(&("vgg16".into(), "YR-P".into())),
    ) {
        println!(
            "shape check [VGG16]: YR-P energy {} KC-P energy (paper: YR-P more efficient); KC-P runtime {} YR-P",
            if yr_en < kc_en { "<" } else { ">=" },
            if kc_rt < yr_rt { "<" } else { ">=" },
        );
    }
    if let (Some(&(kc_rt, _)), Some(&(yx_rt, _))) = (
        results.get(&("unet".into(), "KC-P".into())),
        results.get(&("unet".into(), "YX-P".into())),
    ) {
        println!(
            "shape check [UNet]: YX-P runtime {} KC-P runtime (paper: YX-P faster on UNet)",
            if yx_rt < kc_rt { "<" } else { ">=" },
        );
    }

    // ---- (f): operator-class averages + adaptive --------------------
    section("Fig 10 (f): per-operator-class best dataflow + adaptive gains");
    let mut tf = Table::new(&["op class", "layers", "best static df", "adaptive runtime gain", "adaptive energy gain"]);
    for class in OpClass::all() {
        let mut per_df_runtime: BTreeMap<String, f64> = BTreeMap::new();
        let mut per_df_energy: BTreeMap<String, f64> = BTreeMap::new();
        let mut adaptive_runtime = 0.0;
        let mut adaptive_energy = 0.0;
        let mut n = 0u32;
        for model in zoo::FIG10_MODELS {
            let net = zoo::by_name(model).unwrap();
            for layer in net.layers_of(class) {
                let mut best_rt = f64::INFINITY;
                let mut best_en = f64::INFINITY;
                for df in &dataflows {
                    if let Ok(s) = analyze_layer(layer, df, &hw) {
                        *per_df_runtime.entry(df.name.clone()).or_insert(0.0) += s.runtime;
                        *per_df_energy.entry(df.name.clone()).or_insert(0.0) += s.energy.total();
                        best_rt = best_rt.min(s.runtime);
                        best_en = best_en.min(s.energy.total());
                    }
                }
                if best_rt.is_finite() {
                    adaptive_runtime += best_rt;
                    adaptive_energy += best_en;
                    n += 1;
                }
            }
        }
        if n == 0 {
            continue;
        }
        let (best_df, best_static) = per_df_runtime
            .iter()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(k, v)| (k.clone(), *v))
            .unwrap();
        let best_static_en = per_df_energy.values().cloned().fold(f64::INFINITY, f64::min);
        tf.row(&[
            class.name().to_string(),
            n.to_string(),
            best_df,
            format!("{:.1}%", (1.0 - adaptive_runtime / best_static) * 100.0),
            format!("{:.1}%", (1.0 - adaptive_energy / best_static_en) * 100.0),
        ]);
    }
    print!("{}", tf.render());

    // Whole-suite adaptive summary (the paper's 37% / 10% headline is
    // vs per-model static dataflows).
    let mut static_best_rt = 0.0;
    let mut static_best_en = 0.0;
    let mut adpt_rt = 0.0;
    let mut adpt_en = 0.0;
    for model in zoo::FIG10_MODELS {
        let net = zoo::by_name(model).unwrap();
        let mut best_rt = f64::INFINITY;
        let mut best_en = f64::INFINITY;
        for df in &dataflows {
            if let Ok(s) = analyze_network(&net, df, &hw, true) {
                best_rt = best_rt.min(s.runtime);
                best_en = best_en.min(s.energy.total());
            }
        }
        static_best_rt += best_rt;
        static_best_en += best_en;
        adpt_rt += adaptive_network(&net, &dataflows, &hw, Objective::Runtime).unwrap().runtime;
        adpt_en += adaptive_network(&net, &dataflows, &hw, Objective::Energy).unwrap().energy.total();
    }
    println!(
        "adaptive vs best-static-per-model: runtime -{:.1}%, energy -{:.1}%  (paper: ~37% / ~10% vs a single static dataflow)",
        (1.0 - adpt_rt / static_best_rt) * 100.0,
        (1.0 - adpt_en / static_best_en) * 100.0
    );

    bench("fig10 full grid (5 models x 5 dataflows)", 0, 3, || {
        let mut acc = 0.0;
        for model in zoo::FIG10_MODELS {
            let net = zoo::by_name(model).unwrap();
            for df in &dataflows {
                if let Ok(s) = analyze_network(&net, df, &hw, true) {
                    acc += s.runtime;
                }
            }
        }
        acc
    });
}
