//! Design-space exploration (paper §5.2 / Fig 13) with the sharded
//! scalar sweep engine: KC-P mapping variants x PEs x bandwidth under
//! the Eyeriss budget, folded into a streaming Pareto frontier across
//! all cores, plus the full scatter for the plots.
//!
//! ```sh
//! cargo run --release --example dse_explore
//! ```

use std::sync::Arc;

use anyhow::Result;

use maestro::cache::SharedStore;
use maestro::dse::engine::{sweep, SweepConfig};
use maestro::dse::pareto::{best, Optimize};
use maestro::dse::space::DesignSpace;
use maestro::dse::strategy::{SearchBudget, SearchStrategy};
use maestro::model::network::Network;
use maestro::model::zoo::vgg16;
use maestro::report::experiments::{compare_optima, design_space_scatter, frontier_table};

fn main() -> Result<()> {
    let layer = vgg16::conv2();
    let net = Network::single(layer.clone());
    let space = DesignSpace::fig13("kc-p", 12);
    println!(
        "sweeping {} candidate designs (KC-P variants x PEs x bandwidth) under 16 mm2 / 450 mW",
        space.size()
    );
    // keep_all_points feeds the scatter; drop it for paper-scale spaces
    // and work from the streaming frontier alone. The shared store
    // pools every shard's analyses (and could be flushed to disk for
    // warm restarts — e2e_dse demonstrates that leg).
    let store = Arc::new(SharedStore::new());
    let cfg = SweepConfig { keep_all_points: true, cache: Some(Arc::clone(&store)), ..SweepConfig::default() };
    let outcome = sweep(&net, &space, 2, &cfg)?;
    let macs = layer.macs() as f64;
    println!("{}", outcome.stats.summary());
    println!(
        "shared store after sweep: {} cached analyses, {} hits / {} misses pooled across shards",
        store.len(),
        store.hits(),
        store.misses()
    );

    print!("{}", design_space_scatter(&outcome.points, macs, "KC-P on VGG16-CONV2"));

    println!("Pareto frontier (first 12 of {}):", outcome.frontier.len());
    let head = &outcome.frontier[..outcome.frontier.len().min(12)];
    print!("{}", frontier_table(head, macs).render());

    for (name, o) in [("throughput", Optimize::Throughput), ("energy", Optimize::Energy), ("EDP", Optimize::Edp)] {
        if let Some(p) = best(&outcome.points, o, macs) {
            println!(
                "{name}-optimal: {} pes={} bw={} thrpt={:.1} energy={:.1}uJ area={:.2}mm2 power={:.0}mW",
                p.dataflow, p.pes, p.bandwidth, p.throughput(macs), p.energy_pj / 1e6, p.area_mm2, p.power_mw
            );
        }
    }
    if let Some(c) = compare_optima(&outcome.points, macs) {
        println!(
            "energy-opt vs throughput-opt: power x{:.2}, SRAM x{:.1}, EDP -{:.0}%, throughput {:.0}%",
            c.power_ratio, c.sram_ratio, c.edp_improvement * 100.0, c.throughput_fraction * 100.0
        );
    }

    // The same space through the budgeted search strategies: a seeded
    // uniform sample at a quarter of the space, and Pareto-guided
    // refinement (converges on its own; no budget needed). Both pool
    // the same shared store, so repeated (shape, variant, PEs) triples
    // replay instead of re-analyzing.
    println!("\nsearch strategies on the same space (exhaustive above for reference):");
    for (label, strategy, budget) in [
        (
            "random (25% budget)",
            SearchStrategy::RandomSample { seed: 7 },
            SearchBudget { max_designs: space.size() / 4, ..SearchBudget::default() },
        ),
        ("guided", SearchStrategy::ParetoGuided, SearchBudget::default()),
    ] {
        let cfg = SweepConfig {
            strategy,
            budget,
            cache: Some(Arc::clone(&store)),
            ..SweepConfig::default()
        };
        let out = sweep(&net, &space, 2, &cfg)?;
        println!("  {label}: {}", out.stats.summary());
        println!(
            "    frontier {} point(s) vs exhaustive {}, at ~{:.0}% of the exhaustive evaluations",
            out.frontier.len(),
            outcome.frontier.len(),
            out.stats.evaluated as f64 / outcome.stats.evaluated.max(1) as f64 * 100.0
        );
    }
    Ok(())
}
