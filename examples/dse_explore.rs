//! Design-space exploration (paper §5.2 / Fig 13) with the pure-Rust
//! scalar backend: sweep KC-P mapping variants x PEs x bandwidth under
//! the Eyeriss budget and print the Pareto picture.
//!
//! ```sh
//! cargo run --release --example dse_explore
//! ```

use anyhow::Result;

use maestro::dse::engine::sweep;
use maestro::dse::pareto::{best, pareto_front, Optimize};
use maestro::dse::space::DesignSpace;
use maestro::model::zoo::vgg16;
use maestro::report::experiments::{compare_optima, design_space_scatter};
use maestro::util::table::Table;

fn main() -> Result<()> {
    let layer = vgg16::conv2();
    let space = DesignSpace::fig13("kc-p", 12);
    println!(
        "sweeping {} candidate designs (KC-P variants x PEs x bandwidth) under 16 mm2 / 450 mW",
        space.size()
    );
    let (points, stats) = sweep(&[&layer], &space, 2)?;
    let macs = layer.macs() as f64;
    println!(
        "evaluated {} ({} skipped by budget pruning), {} valid, {:.2}s -> {:.0} designs/s",
        stats.evaluated,
        stats.total_designs - stats.evaluated,
        stats.valid,
        stats.seconds,
        stats.rate()
    );

    print!("{}", design_space_scatter(&points, macs, "KC-P on VGG16-CONV2"));

    let front = pareto_front(&points, |p| p.runtime, |p| p.energy_pj);
    let mut t = Table::new(&["variant", "PEs", "BW", "L1 (el)", "L2 (el)", "thrpt (MAC/cyc)", "energy (uJ)", "area", "power"]);
    for &i in front.iter().take(12) {
        let p = &points[i];
        t.row(&[
            p.dataflow.clone(),
            p.pes.to_string(),
            p.bandwidth.to_string(),
            p.l1.to_string(),
            p.l2.to_string(),
            format!("{:.1}", p.throughput(macs)),
            format!("{:.1}", p.energy_pj / 1e6),
            format!("{:.2}", p.area_mm2),
            format!("{:.0}", p.power_mw),
        ]);
    }
    println!("Pareto front (first 12 of {}):", front.len());
    print!("{}", t.render());

    for (name, o) in [("throughput", Optimize::Throughput), ("energy", Optimize::Energy), ("EDP", Optimize::Edp)] {
        if let Some(p) = best(&points, o, macs) {
            println!(
                "{name}-optimal: {} pes={} bw={} thrpt={:.1} energy={:.1}uJ area={:.2}mm2 power={:.0}mW",
                p.dataflow, p.pes, p.bandwidth, p.throughput(macs), p.energy_pj / 1e6, p.area_mm2, p.power_mw
            );
        }
    }
    if let Some(c) = compare_optima(&points, macs) {
        println!(
            "energy-opt vs throughput-opt: power x{:.2}, SRAM x{:.1}, EDP -{:.0}%, throughput {:.0}%",
            c.power_ratio, c.sram_ratio, c.edp_improvement * 100.0, c.throughput_fraction * 100.0
        );
    }
    Ok(())
}
