//! Model validation (paper Fig 9): compare the analytical engine against
//! the cycle-level schedule simulator on a matrix of layers x dataflows.
//!
//! ```sh
//! cargo run --release --example validate_model
//! ```

use anyhow::Result;

use maestro::engine::analysis::analyze_layer;
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::model::layer::Layer;
use maestro::sim::cycle::simulate;
use maestro::util::table::{num, Table};

fn main() -> Result<()> {
    let layers = vec![
        Layer::conv2d("small-early", 1, 16, 8, 34, 34, 3, 3, 1),
        Layer::conv2d("small-late", 1, 64, 64, 16, 16, 3, 3, 1),
        Layer::conv2d("pointwise", 1, 64, 32, 28, 28, 1, 1, 1),
        Layer::conv2d("strided", 1, 32, 16, 33, 33, 3, 3, 2),
        Layer::depthwise("depthwise", 1, 32, 30, 30, 3, 3, 1),
    ];
    let hw = HwConfig { num_pes: 64, ..HwConfig::fig10_default() };

    let mut t = Table::new(&["layer", "dataflow", "sim cycles", "model cycles", "error %"]);
    let mut errs: Vec<f64> = Vec::new();
    for layer in &layers {
        for df in styles::all_styles() {
            let Ok(sim) = simulate(layer, &df, &hw, 30_000_000) else { continue };
            let Ok(ana) = analyze_layer(layer, &df, &hw) else { continue };
            let err = (ana.runtime - sim.cycles).abs() / sim.cycles * 100.0;
            errs.push(err);
            t.row(&[layer.name.clone(), df.name.clone(), num(sim.cycles), num(ana.runtime), format!("{err:.2}")]);
        }
    }
    print!("{}", t.render());
    let avg = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
    println!("\naverage |error| over {} (layer, dataflow) pairs: {avg:.2}% (paper: 3.9% vs RTL)", errs.len());
    Ok(())
}
