//! End-to-end driver: the full three-layer system on a real workload.
//!
//! Pipeline proven here (all layers composing):
//!   1. Rust analysis engines flatten (VGG16 conv stack, KC-P + YR-P
//!      mapping variants, PE sweep) into case tables.
//!   2. The coordinator batches design points and routes them to the
//!      **AOT-compiled PJRT evaluator** — the L1 Pallas kernel lowered
//!      through the L2 JAX graph into `artifacts/dse_eval.hlo.txt` —
//!      with worker threads, bounded queues, and metrics.
//!   3. Results are cross-checked against the scalar Rust evaluator,
//!      Pareto-analyzed, and the paper's headline DSE numbers reported.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_dse
//! ```
//!
//! Output is recorded in EXPERIMENTS.md (experiment X1).

use std::sync::Arc;

use anyhow::Result;

use maestro::cache::SharedStore;
use maestro::coordinator::{run_jobs, Backend, DseJob};
use maestro::dse::engine::{sweep, SweepConfig};
use maestro::dse::pareto::{best, pareto_front, Optimize};
use maestro::dse::space::{geometric_range, kc_p_variants, yr_p_variants, DesignSpace};
use maestro::model::zoo::vgg16;
use maestro::report::experiments::compare_optima;
use maestro::runtime::{evaluate_scalar, BatchEvaluator, DesignIn};
use maestro::util::benchkit::fmt_rate;
use maestro::util::table::Table;

fn main() -> Result<()> {
    let artifact = BatchEvaluator::default_path();
    let backend = if artifact.exists() {
        println!("backend: PJRT artifact {}", artifact.display());
        Backend::Pjrt(artifact)
    } else {
        println!("backend: scalar (run `make artifacts` for the PJRT path)");
        Backend::Scalar
    };

    // Workload: the full VGG16 conv stack (13 layers, one case table).
    let net = vgg16::conv_only();
    let layer_refs: Vec<&maestro::model::layer::Layer> = net.layers.iter().collect();
    println!("workload: {} ({} layers, {:.2} GMACs)", net.name, net.layers.len(), net.macs() as f64 / 1e9);

    // Stage 0: the sharded scalar sweep (streaming frontier, no PJRT) —
    // the memory-bounded baseline the coordinator path is compared to.
    // The workload is the whole network: all shards pool one shared
    // store, so the conv stack's repeated shapes dedupe across the
    // worker pool (see cache=h/d/m in the summaries).
    let space = DesignSpace::fig13("kc-p", 10);
    let store = Arc::new(SharedStore::new());
    let serial = sweep(&net, &space, 2, &SweepConfig::serial())?;
    let cfg = SweepConfig { cache: Some(Arc::clone(&store)), ..SweepConfig::default() };
    let sharded = sweep(&net, &space, 2, &cfg)?;
    println!("sharded sweep, 1 thread:   {}", serial.stats.summary());
    println!("sharded sweep, all cores:  {}", sharded.stats.summary());
    println!(
        "thread scaling: {:.2}x on {} cores; frontier {} points (identical across thread counts: {})",
        serial.stats.seconds / sharded.stats.seconds.max(1e-9),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        sharded.frontier.len(),
        serial.frontier == sharded.frontier,
    );

    // Stage 0b: warm-start persistence. Flush the cold sweep's store,
    // reload it "in a new process" (a fresh store), and re-run: every
    // analysis replays from disk and the outcome is bit-identical.
    let cache_path = std::env::temp_dir().join(format!("maestro_e2e_dse_{}.mcache", std::process::id()));
    let flushed = store.flush(&cache_path)?;
    println!(
        "cache flush: {} records ({} total) -> {}",
        flushed.written,
        flushed.total,
        cache_path.display()
    );
    let warm_store = Arc::new(SharedStore::new());
    let loaded = warm_store.load(&cache_path);
    if let Some(w) = &loaded.warning {
        eprintln!("cache load: {w}");
    }
    let warm_cfg = SweepConfig { cache: Some(Arc::clone(&warm_store)), ..SweepConfig::default() };
    let warm = sweep(&net, &space, 2, &warm_cfg)?;
    println!("warm restart ({} records loaded): {}", loaded.loaded, warm.stats.summary());
    println!(
        "warm run: {} disk hits, {} misses, frontier identical to cold: {} | cold {:.2}s -> warm {:.2}s",
        warm.stats.cache_disk_hits,
        warm.stats.cache_misses,
        warm.frontier == sharded.frontier,
        sharded.stats.seconds,
        warm.stats.seconds,
    );
    assert!(warm.stats.cache_disk_hits > 0, "warm restart must hit the disk-loaded entries");
    assert_eq!(warm.frontier, sharded.frontier, "warm restart must not move a bit");
    std::fs::remove_file(&cache_path).ok();

    // Design axes: mapping variants x PEs (jobs), bandwidth (designs).
    let designs: Vec<DesignIn> = geometric_range(1, 256, 48)
        .into_iter()
        .map(|bw| DesignIn { bandwidth: bw as f64, latency: 2.0, l1: 0.0, l2: 0.0 })
        .collect();
    let mut variants = kc_p_variants();
    variants.extend(yr_p_variants());
    let mut jobs = Vec::new();
    let mut id = 0u64;
    for variant in &variants {
        for pes in geometric_range(16, 1024, 16) {
            id += 1;
            jobs.push(DseJob {
                id,
                network: net.clone(),
                variant: variant.clone(),
                pes,
                designs: designs.clone(),
                noc_hops: 2,
                area_budget: 16.0,
                power_budget: 450.0,
            });
        }
    }
    let total_designs: u64 = jobs.iter().map(|j| j.designs.len() as u64).sum();
    println!("jobs: {} (variants x PEs), {} design points total", jobs.len(), total_designs);

    let t0 = std::time::Instant::now();
    let (results, metrics) = run_jobs(jobs, backend, 4)?;
    let wall = t0.elapsed().as_secs_f64();
    println!("coordinator: {}", metrics.summary(wall));
    println!(
        "effective DSE rate: {}/s (paper: 0.17M designs/s average on an i7-8700k)",
        fmt_rate(total_designs as f64 / wall)
    );

    // Cross-check a sample of PJRT results against the scalar oracle.
    let sample = results.iter().find(|r| !r.outputs.is_empty()).expect("some job mapped");
    let sample_job_variant = variants
        .iter()
        .find(|v| v.name == sample.dataflow)
        .expect("variant by name");
    let table = maestro::dse::engine::build_case_table(&layer_refs, sample_job_variant, sample.pes)?;
    let ds: Vec<DesignIn> = sample.outputs.iter().map(|(d, _)| *d).collect();
    let oracle = evaluate_scalar(&table, &ds, 2, 16.0, 450.0);
    let mut worst = 0.0f64;
    for ((_, got), want) in sample.outputs.iter().zip(&oracle) {
        worst = worst.max((got.runtime - want.runtime).abs() / want.runtime.max(1.0));
    }
    println!("cross-check vs scalar oracle (job {} / {}): worst rel err {:.2e}", sample.id, sample.dataflow, worst);
    assert!(worst < 5e-3, "backends disagree");

    // Pareto analysis over everything.
    let mut points = Vec::new();
    let mut macs = 0.0f64;
    for r in &results {
        macs = macs.max(r.macs);
        points.extend(r.points());
    }
    let valid = points.iter().filter(|p| p.valid).count();
    println!("designs: {} total, {} valid ({:.1}%)", points.len(), valid, valid as f64 / points.len().max(1) as f64 * 100.0);
    let front = pareto_front(&points, |p| p.runtime, |p| p.energy_pj);
    println!("runtime-energy Pareto front: {} points", front.len());

    let mut t = Table::new(&["objective", "dataflow", "PEs", "BW", "thrpt (MAC/cyc)", "energy (mJ)", "area (mm2)", "power (mW)"]);
    for (name, o) in [("throughput", Optimize::Throughput), ("energy", Optimize::Energy), ("EDP", Optimize::Edp)] {
        if let Some(p) = best(&points, o, macs) {
            t.row(&[
                name.into(),
                p.dataflow.clone(),
                p.pes.to_string(),
                p.bandwidth.to_string(),
                format!("{:.1}", p.throughput(macs)),
                format!("{:.2}", p.energy_pj / 1e9),
                format!("{:.2}", p.area_mm2),
                format!("{:.0}", p.power_mw),
            ]);
        }
    }
    print!("{}", t.render());

    if let Some(c) = compare_optima(&points, macs) {
        println!(
            "energy-opt vs throughput-opt: power x{:.2} (paper 2.16x), SRAM x{:.1} (paper 10.6x), PEs {:.0}% (paper 80%), EDP -{:.0}% (paper 65%), throughput {:.0}% (paper 62%)",
            c.power_ratio, c.sram_ratio, c.pe_ratio * 100.0, c.edp_improvement * 100.0, c.throughput_fraction * 100.0
        );
    }
    println!("\ne2e OK: analysis -> coordinator -> PJRT artifact -> Pareto, Python never on the request path.");
    Ok(())
}
