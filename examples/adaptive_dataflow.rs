//! Adaptive dataflow (paper §5.1 / Fig 10f): per-layer dataflow
//! selection over MobileNetV2 — the workload whose mixed operator types
//! (pointwise, depthwise, residual) motivate adaptivity.
//!
//! ```sh
//! cargo run --release --example adaptive_dataflow
//! ```

use std::sync::Arc;

use anyhow::Result;

use maestro::cache::SharedStore;
use maestro::engine::analysis::{adaptive_network_with, analyze_network_with, Analyzer, Objective};
use maestro::hw::config::HwConfig;
use maestro::ir::styles;
use maestro::mapspace::{Mapper, MapperConfig};
use maestro::model::zoo;
use maestro::util::table::{num, Table};

fn main() -> Result<()> {
    let net = zoo::by_name("mobilenetv2")?;
    let hw = HwConfig::fig10_default();
    let candidates = styles::all_styles();
    println!("{}: {} layers, {} unique shapes", net.name, net.layers.len(), net.unique_shapes().len());

    // One SharedStore-backed Analyzer for every run below: the static
    // baselines already warm the store the adaptive pass then replays —
    // each (shape, dataflow structure) pair is analyzed exactly once
    // across the whole example. (The same store could be handed to a
    // DSE sweep, or flushed to disk — see the e2e_dse example.)
    let store = Arc::new(SharedStore::new());
    let mut analyzer = Analyzer::with_store(Arc::clone(&store));

    // Static baselines.
    let mut t = Table::new(&["dataflow", "runtime (Mcyc)", "energy (uJ)", "layers mapped", "skipped"]);
    let mut best_static = f64::INFINITY;
    for df in &candidates {
        if let Ok(s) = analyze_network_with(&mut analyzer, &net, df, &hw, true) {
            best_static = best_static.min(s.runtime);
            t.row(&[
                df.name.clone(),
                format!("{:.2}", s.runtime / 1e6),
                num(s.energy.total() / 1e6),
                s.per_layer.len().to_string(),
                s.skipped.len().to_string(),
            ]);
        }
    }
    let adaptive = adaptive_network_with(&mut analyzer, &net, &candidates, &hw, Objective::Runtime)?;
    t.row(&[
        "adaptive".into(),
        format!("{:.2}", adaptive.runtime / 1e6),
        num(adaptive.energy.total() / 1e6),
        adaptive.per_layer.len().to_string(),
        adaptive.skipped.len().to_string(),
    ]);
    // The mapspace mapper: adaptive again, but over the *generated*
    // tiling space of every style template instead of the five fixed
    // Table 3 points (same shared store — structural fingerprints mean
    // identical tilings replay across both passes).
    let mut mapper = Mapper::with_store(Arc::clone(&store));
    let mapped = mapper.map_network(&net, &hw, &MapperConfig::default())?;
    t.row(&[
        "mapper".into(),
        format!("{:.2}", mapped.network.runtime / 1e6),
        num(mapped.network.energy.total() / 1e6),
        mapped.network.per_layer.len().to_string(),
        mapped.network.skipped.len().to_string(),
    ]);
    print!("{}", t.render());
    println!("{}", mapped.stats.summary());
    println!(
        "shared store: {} hits / {} misses ({} entries) across {} static + 1 adaptive runs",
        analyzer.cache_hits(),
        analyzer.cache_misses(),
        store.len(),
        candidates.len()
    );
    println!(
        "\nadaptive runtime gain vs best static: {:.1}% (paper reports ~37% across models vs one static dataflow)",
        (1.0 - adaptive.runtime / best_static) * 100.0
    );

    // Which dataflow won where?
    let mut wins = Table::new(&["layer", "op", "winning dataflow", "runtime (Kcyc)"]);
    for s in adaptive.per_layer.iter().take(24) {
        let op = net
            .layers
            .iter()
            .find(|l| l.name == s.layer)
            .map(|l| l.op.name())
            .unwrap_or("?");
        wins.row(&[s.layer.clone(), op.into(), s.dataflow.clone(), format!("{:.1}", s.runtime / 1e3)]);
    }
    println!("\nper-layer winners (first 24 layers):");
    print!("{}", wins.render());
    Ok(())
}
