//! Quickstart: analyze one convolution layer under the five Table 3
//! dataflows and print runtime, energy, reuse, and buffer requirements.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use maestro::engine::analysis::{algorithmic_max_reuse, analyze_layer};
use maestro::hw::config::HwConfig;
use maestro::ir::{parser, styles};
use maestro::model::layer::Layer;
use maestro::model::tensor::TensorKind;
use maestro::util::table::{num, Table};

fn main() -> Result<()> {
    // A layer: VGG16-style conv, 64 -> 128 channels at 112x112.
    let layer = Layer::conv2d("demo", 1, 128, 64, 114, 114, 3, 3, 1);
    // Hardware: 256 PEs, 16 elements/cycle NoC, 2KB L1 / 1MB L2.
    let hw = HwConfig::fig10_default();

    println!("layer: {layer}");
    println!("hw: {} PEs, NoC {} el/cyc, L1 {} el, L2 {} el\n", hw.num_pes, hw.noc_bandwidth, hw.l1_size, hw.l2_size);

    let mut t = Table::new(&[
        "dataflow", "runtime (cyc)", "util", "energy (uJ)", "filter reuse", "input reuse", "L1 req (el)", "peak BW",
    ]);
    for df in styles::all_styles() {
        let s = analyze_layer(&layer, &df, &hw)?;
        t.row(&[
            df.name.clone(),
            num(s.runtime),
            format!("{:.2}", s.util),
            num(s.energy.total() / 1e6),
            format!("{:.1}", s.reuse_factor(TensorKind::Filter)),
            format!("{:.1}", s.reuse_factor(TensorKind::Input)),
            s.l1_req.to_string(),
            format!("{:.1}", s.peak_bw_need),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nalgorithmic max reuse: filter {:.1}, input {:.1}",
        algorithmic_max_reuse(&layer, TensorKind::Filter),
        algorithmic_max_reuse(&layer, TensorKind::Input)
    );

    // Dataflows are plain text — write your own:
    let custom = parser::parse_dataflow(
        "Dataflow my-ws {
            TemporalMap(1,1) K;
            TemporalMap(4,4) C;
            TemporalMap(Sz(R),1) Y;
            SpatialMap(Sz(S),1) X;
         }",
    )?;
    let s = analyze_layer(&layer, &custom, &hw)?;
    println!("\ncustom dataflow '{}' runtime: {} cycles (util {:.2})", custom.name, num(s.runtime), s.util);
    Ok(())
}
